//! Host-engine networks: forward + analytic backward for the sim model
//! zoo, hand-derived over the blocked GEMMs in [`crate::tensor::par`].
//!
//! Two trunk families cover every zoo model:
//!
//! * **mlp / denoiser** — `x → relu(x·W_in + b_in) → relu(a₁·W_hid +
//!   b_hid) → head`, the Figure-7 / DreamBooth-sim shapes with the single
//!   adapted `hid.w` site.
//! * **encoder / decoder / vit** — embedding (token+position, or
//!   patch+position), one parameter-free cross-token [`Mix`] (mean over
//!   the sequence for encoder/vit, causal prefix mean for decoder — the
//!   attention stand-in that keeps lm/mlm from degenerating into
//!   conditional-unigram models), then `layers` residual blocks with two
//!   adapted projections per block (the paper's q/v sites):
//!
//!   ```text
//!   h ← h + relu(h·(W_q + ΔW_q) + b_q)
//!   h ← h + relu(h·(W_v + ΔW_v) + b_v)
//!   ```
//!
//!   Classification/regression heads mean-pool over tokens; lm/mlm heads
//!   project every position to the vocabulary. (The blocks are residual
//!   MLP mixers, not attention — the sim protocol compares *adapter
//!   parameterizations* on a fixed backbone, and a mixer keeps the
//!   hand-written backward small and exactly reproducible. Host-side
//!   generation/LM numbers are therefore *not* comparable to `--engine
//!   xla` runs or the paper; the comparison *structure* across methods
//!   is.)
//!
//! Backward is a plain tape: every pre-activation is kept from the
//! forward pass, and ∂L/∂W_eff is produced only for sites something
//! trains (the engine's site bindings, biases for bitfit/ff, embeddings
//! for ff). All reductions run in the same order every call, so training
//! is bitwise deterministic for a fixed seed.

use super::zoo::{self, ModelCfg};
use crate::tensor::{par, Tensor};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};

/// Where a logical tensor lives in the engine state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loc {
    Base(usize),
    Adapt(usize),
}

/// Indices of one transformer block's tensors.
#[derive(Debug, Clone)]
pub struct Block {
    pub wq: usize,
    pub bq: usize,
    pub wv: usize,
    pub bv: usize,
    /// Houlsby bottleneck (adapt indices of `adpt.blk{i}.{d,u}`), if the
    /// method is `adapter`.
    pub adpt: Option<(usize, usize)>,
}

/// Index layout of the mlp/denoiser trunk.
#[derive(Debug, Clone)]
pub struct MlpIdx {
    pub in_w: usize,
    pub in_b: usize,
    pub hid_w: usize,
    pub hid_b: usize,
    pub adpt: Option<(usize, usize)>,
}

/// Embedding layout of the transformer trunk.
#[derive(Debug, Clone)]
pub enum Embed {
    /// `tok_emb[x] + pos_emb` (encoder / decoder).
    Tokens { tok: usize, pos: usize },
    /// `patchify(x)·patch_emb + pos_emb` (vit).
    Patch { emb: usize, pos: usize },
}

/// Parameter-free token mixing applied once after the embedding, standing
/// in for attention's cross-token information flow: without it every
/// position would be a function of its own (token, position) pair alone
/// and the lm/mlm objectives would collapse to conditional-unigram
/// models. Linear and parameter-free, so the backward pass is the exact
/// transpose and needs no tape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// No mixing (mlp / denoiser trunks have one "token").
    None,
    /// `h_r += mean_s(h_s)` over the full sequence (encoder / vit —
    /// bidirectional, like unmasked attention).
    Full,
    /// `h_r += mean_{s ≤ r}(h_s)` (decoder — causal prefix mean, so
    /// greedy generation never peeks ahead).
    Causal,
}

/// Apply [`Mix`] to `[b, t, d]` activations.
fn mix_fwd(mix: Mix, h: &[f32], b: usize, t: usize, d: usize) -> Vec<f32> {
    let mut out = h.to_vec();
    match mix {
        Mix::None => {}
        Mix::Full => {
            for bi in 0..b {
                let seq = &h[bi * t * d..(bi + 1) * t * d];
                let mut mean = vec![0.0f32; d];
                for r in 0..t {
                    add_into(&mut mean, &seq[r * d..(r + 1) * d]);
                }
                for v in &mut mean {
                    *v /= t as f32;
                }
                let oseq = &mut out[bi * t * d..(bi + 1) * t * d];
                for r in 0..t {
                    add_into(&mut oseq[r * d..(r + 1) * d], &mean);
                }
            }
        }
        Mix::Causal => {
            for bi in 0..b {
                let mut sum = vec![0.0f32; d];
                for r in 0..t {
                    let idx = (bi * t + r) * d;
                    add_into(&mut sum, &h[idx..idx + d]);
                    let inv = 1.0 / (r as f32 + 1.0);
                    let orow = &mut out[idx..idx + d];
                    for (o, &s) in orow.iter_mut().zip(&sum) {
                        *o += s * inv;
                    }
                }
            }
        }
    }
    out
}

/// Transpose of [`mix_fwd`]: with `y = (I + M)·x`, `∂L/∂x = (I + Mᵀ)·∂L/∂y`.
/// `Full`'s M is symmetric (uniform averaging), `Causal`'s transpose is a
/// weighted suffix sum: `∂L/∂x_s = ∂L/∂y_s + Σ_{r ≥ s} ∂L/∂y_r / (r+1)`.
fn mix_bwd(mix: Mix, dy: &[f32], b: usize, t: usize, d: usize) -> Vec<f32> {
    match mix {
        Mix::None | Mix::Full => mix_fwd(mix, dy, b, t, d),
        Mix::Causal => {
            let mut out = dy.to_vec();
            for bi in 0..b {
                let mut acc = vec![0.0f32; d];
                for r in (0..t).rev() {
                    let idx = (bi * t + r) * d;
                    let inv = 1.0 / (r as f32 + 1.0);
                    let drow = &dy[idx..idx + d];
                    for (a, &dv) in acc.iter_mut().zip(drow) {
                        *a += dv * inv;
                    }
                    // out already holds dy_r; add the (r-inclusive) suffix sum.
                    let orow = &mut out[idx..idx + d];
                    for (o, &a) in orow.iter_mut().zip(&acc) {
                        *o += a;
                    }
                }
            }
            out
        }
    }
}

/// Loss family of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Loss {
    Ce,
    Mse,
    /// Masked per-position cross-entropy (lm and mlm share the math).
    Lm,
    MseImg,
}

impl Loss {
    pub fn parse(s: &str) -> Result<Loss> {
        Ok(match s {
            "ce" => Loss::Ce,
            "mse" => Loss::Mse,
            "lm" | "mlm" => Loss::Lm,
            "mseimg" => Loss::MseImg,
            other => bail!("unknown loss '{other}'"),
        })
    }
}

/// What the backward pass must produce.
#[derive(Debug, Default)]
pub struct Needs {
    /// Base indices of 2-D weights whose ∂L/∂W_eff is consumed.
    pub w: HashSet<usize>,
    /// Base indices of biases whose ∂L/∂b_eff is consumed.
    pub b: HashSet<usize>,
    /// Task-head gradients (head trained).
    pub head: bool,
}

/// Gradients out of one backward pass.
#[derive(Debug, Default)]
pub struct Grads {
    /// ∂L/∂(effective base tensor), keyed by base index — the upstream
    /// gradients the method adjoints (`site_delta_grad`) consume.
    pub base: HashMap<usize, Vec<f32>>,
    /// Direct adapt-tensor gradients (task head, Houlsby adapters),
    /// keyed by adapt index.
    pub adapt: HashMap<usize, Vec<f32>>,
}

/// Resolved effective weights: base tensors with ΔW folded in where a
/// method adapts the site.
pub struct Weights<'a> {
    pub base: &'a [Tensor],
    pub eff: &'a HashMap<usize, Vec<f32>>,
}

impl Weights<'_> {
    pub fn get(&self, i: usize) -> Result<&[f32]> {
        match self.eff.get(&i) {
            Some(v) => Ok(v.as_slice()),
            None => self.base[i].as_f32(),
        }
    }
}

/// One zoo network: trunk layout + loss, with all tensor indices resolved
/// against the artifact meta's role groups.
pub struct Net {
    pub model: &'static ModelCfg,
    pub loss: Loss,
    pub head_w: Loc,
    pub head_b: Loc,
    pub embed: Option<Embed>,
    pub mix: Mix,
    pub blocks: Vec<Block>,
    pub mlp: Option<MlpIdx>,
}

/// Activation tape of one forward pass (transformer trunk).
struct BlockTape {
    h_in: Vec<f32>,
    uq: Vec<f32>,
    h_mid: Vec<f32>,
    uv: Vec<f32>,
    h_out: Vec<f32>,
    z: Option<Vec<f32>>,
    a3: Option<Vec<f32>>,
}

/// Full tape: enough to run backward without recomputing anything.
pub struct Tape {
    rows: usize,
    // transformer trunk
    toks: Option<Vec<usize>>,
    patch: Option<Vec<f32>>,
    blocks: Vec<BlockTape>,
    h_last: Vec<f32>,
    pooled: Option<Vec<f32>>,
    // mlp trunk
    x: Option<Vec<f32>>,
    u1: Option<Vec<f32>>,
    a1: Option<Vec<f32>>,
    u2: Option<Vec<f32>>,
    a2: Option<Vec<f32>>,
    // shared adapter-after-trunk slots (mlp trunk only)
    z: Option<Vec<f32>>,
    a3: Option<Vec<f32>>,
    /// What the head consumed: pooled / h_last / post-adapter a2.
    head_in: Vec<f32>,
    /// ∂L/∂logits, already normalized.
    pub dlogits: Vec<f32>,
}

/// Forward output.
pub struct Fwd {
    pub loss: f32,
    pub logits: Tensor,
    pub tape: Option<Tape>,
}

// ---------------------------------------------------------------------------
// Small dense helpers (row-major slices).

fn transpose(v: &[f32], m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = v[i * n + j];
        }
    }
    out
}

fn add_bias_rows(y: &mut [f32], b: &[f32], rows: usize, n: usize) {
    for r in 0..rows {
        let row = &mut y[r * n..(r + 1) * n];
        for (slot, &bv) in row.iter_mut().zip(b) {
            *slot += bv;
        }
    }
}

fn relu(v: &[f32]) -> Vec<f32> {
    v.iter().map(|&x| if x > 0.0 { x } else { 0.0 }).collect()
}

/// dy ⊙ 1[pre > 0], returning a new vector.
fn relu_bwd(dy: &[f32], pre: &[f32]) -> Vec<f32> {
    dy.iter().zip(pre).map(|(&d, &p)| if p > 0.0 { d } else { 0.0 }).collect()
}

fn colsum(dy: &[f32], rows: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for r in 0..rows {
        let row = &dy[r * n..(r + 1) * n];
        for (slot, &v) in out.iter_mut().zip(row) {
            *slot += v;
        }
    }
    out
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a += b;
    }
}

/// `Xᵀ·dY`: the weight gradient of `Y = X·W` (X: [rows, k], dY: [rows, n]).
fn weight_grad(x: &[f32], dy: &[f32], rows: usize, k: usize, n: usize) -> Vec<f32> {
    par::matmul_f32(&transpose(x, rows, k), dy, k, rows, n)
}

/// Softmax cross-entropy over `rows` rows with optional per-row weights;
/// returns (mean loss, normalized ∂L/∂logits).
fn softmax_ce(
    logits: &[f32],
    rows: usize,
    classes: usize,
    targets: &[i32],
    weights: Option<&[f32]>,
) -> Result<(f32, Vec<f32>)> {
    let total_w: f64 = match weights {
        Some(w) => w.iter().map(|&x| x as f64).sum(),
        None => rows as f64,
    };
    let mut dl = vec![0.0f32; rows * classes];
    if total_w <= 0.0 {
        return Ok((0.0, dl));
    }
    let mut loss = 0.0f64;
    for r in 0..rows {
        let w = weights.map(|w| w[r]).unwrap_or(1.0);
        if w == 0.0 {
            continue;
        }
        let y = targets[r];
        anyhow::ensure!(
            (0..classes as i32).contains(&y),
            "target {y} out of range for {classes} classes"
        );
        let row = &logits[r * classes..(r + 1) * classes];
        let max = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - max) as f64).exp();
        }
        loss += w as f64 * (sum.ln() + max as f64 - row[y as usize] as f64);
        let drow = &mut dl[r * classes..(r + 1) * classes];
        for (c, slot) in drow.iter_mut().enumerate() {
            let p = ((row[c] - max) as f64).exp() / sum;
            let onehot = if c as i32 == y { 1.0 } else { 0.0 };
            *slot = (w as f64 * (p - onehot) / total_w) as f32;
        }
    }
    Ok(((loss / total_w) as f32, dl))
}

/// Mean squared error over all elements; returns (loss, ∂L/∂pred).
fn mse(pred: &[f32], target: &[f32]) -> (f32, Vec<f32>) {
    let n = pred.len().max(1) as f64;
    let mut loss = 0.0f64;
    let mut dl = vec![0.0f32; pred.len()];
    for i in 0..pred.len() {
        let diff = pred[i] as f64 - target[i] as f64;
        loss += diff * diff;
        dl[i] = (2.0 * diff / n) as f32;
    }
    ((loss / n) as f32, dl)
}

// ---------------------------------------------------------------------------

impl Net {
    /// Resolve the trunk layout from a synthesized artifact meta.
    pub fn build(
        model: &'static ModelCfg,
        loss: &str,
        base_idx: &HashMap<String, usize>,
        adapt_idx: &HashMap<String, usize>,
        has_houlsby: bool,
    ) -> Result<Net> {
        let loss = Loss::parse(loss)?;
        let bi = |name: &str| -> Result<usize> {
            base_idx.get(name).copied().ok_or_else(|| anyhow!("missing base tensor '{name}'"))
        };
        let loc = |name: &str| -> Result<Loc> {
            if let Some(&i) = adapt_idx.get(name) {
                Ok(Loc::Adapt(i))
            } else {
                Ok(Loc::Base(bi(name)?))
            }
        };
        let houlsby = |site: &str| -> Option<(usize, usize)> {
            if !has_houlsby {
                return None;
            }
            let d = adapt_idx.get(&format!("adpt.{site}.d")).copied()?;
            let u = adapt_idx.get(&format!("adpt.{site}.u")).copied()?;
            Some((d, u))
        };
        let mut net = Net {
            model,
            loss,
            head_w: loc("head.w")?,
            head_b: loc("head.b")?,
            embed: None,
            mix: Mix::None,
            blocks: Vec::new(),
            mlp: None,
        };
        match model.kind {
            "mlp" | "denoiser" => {
                net.mlp = Some(MlpIdx {
                    in_w: bi("in.w")?,
                    in_b: bi("in.b")?,
                    hid_w: bi("hid.w")?,
                    hid_b: bi("hid.b")?,
                    adpt: houlsby("hid"),
                });
            }
            "encoder" | "decoder" | "vit" => {
                net.embed = Some(if model.kind == "vit" {
                    Embed::Patch { emb: bi("patch_emb")?, pos: bi("pos_emb")? }
                } else {
                    Embed::Tokens { tok: bi("tok_emb")?, pos: bi("pos_emb")? }
                });
                net.mix = if model.kind == "decoder" { Mix::Causal } else { Mix::Full };
                for i in 0..model.layers {
                    net.blocks.push(Block {
                        wq: bi(&format!("blk{i}.wq"))?,
                        bq: bi(&format!("blk{i}.bq"))?,
                        wv: bi(&format!("blk{i}.wv"))?,
                        bv: bi(&format!("blk{i}.bv"))?,
                        adpt: houlsby(&format!("blk{i}")),
                    });
                }
            }
            other => bail!("host engine has no trunk for model kind '{other}'"),
        }
        Ok(net)
    }

    fn tensor_at<'a>(
        &self,
        loc: Loc,
        base: &'a [Tensor],
        adapt: &'a [Tensor],
    ) -> &'a Tensor {
        match loc {
            Loc::Base(i) => &base[i],
            Loc::Adapt(i) => &adapt[i],
        }
    }

    /// Forward pass (and loss gradient when `want_tape`).
    pub fn forward(
        &self,
        w: &Weights,
        adapt: &[Tensor],
        batch: &HashMap<String, Tensor>,
        want_tape: bool,
    ) -> Result<Fwd> {
        let get_batch = |name: &str| -> Result<&Tensor> {
            batch.get(name).ok_or_else(|| anyhow!("batch missing tensor '{name}'"))
        };
        let head_w_t = self.tensor_at(self.head_w, w.base, adapt).clone();
        let head_b_t = self.tensor_at(self.head_b, w.base, adapt).clone();
        // A trained head reads from `adapt` directly; a frozen (or
        // ff-delta'd) head reads through the effective-weight map.
        let head_w: &[f32] = match self.head_w {
            Loc::Base(i) => w.get(i)?,
            Loc::Adapt(_) => head_w_t.as_f32()?,
        };
        let head_b: &[f32] = match self.head_b {
            Loc::Base(i) => w.get(i)?,
            Loc::Adapt(_) => head_b_t.as_f32()?,
        };

        if let Some(mlp) = &self.mlp {
            return self.forward_mlp(mlp, w, adapt, batch, head_w, head_b, want_tape);
        }

        // --- transformer trunk -------------------------------------------
        let m = self.model;
        let (b, t, d) = (m.batch, m.tokens(), m.d);
        let rows = b * t;
        let embed = self.embed.as_ref().expect("transformer net has an embedding");
        let mut toks: Option<Vec<usize>> = None;
        let mut patch: Option<Vec<f32>> = None;
        let mut h = vec![0.0f32; rows * d];
        match embed {
            Embed::Tokens { tok, pos } => {
                let x = get_batch("x")?;
                anyhow::ensure!(
                    x.shape == [b, t],
                    "batch 'x' shape {:?}, model wants [{b}, {t}]",
                    x.shape
                );
                let ids = x.as_i32()?;
                let te = w.get(*tok)?;
                let pe = w.get(*pos)?;
                let mut tvec = Vec::with_capacity(rows);
                for r in 0..rows {
                    let id = ids[r];
                    anyhow::ensure!(
                        (0..m.vocab as i32).contains(&id),
                        "token id {id} out of range for vocab {}",
                        m.vocab
                    );
                    let id = id as usize;
                    tvec.push(id);
                    let row = &mut h[r * d..(r + 1) * d];
                    let te_row = &te[id * d..(id + 1) * d];
                    let pe_row = &pe[(r % t) * d..(r % t + 1) * d];
                    for j in 0..d {
                        row[j] = te_row[j] + pe_row[j];
                    }
                }
                toks = Some(tvec);
            }
            Embed::Patch { emb, pos } => {
                let x = get_batch("x")?;
                anyhow::ensure!(
                    x.shape == [b, m.img, m.img, 3],
                    "batch 'x' shape {:?}, model wants [{b}, {}, {}, 3]",
                    x.shape,
                    m.img,
                    m.img
                );
                let px = x.as_f32()?;
                let g = m.img / m.patch;
                let ppc = m.patch * m.patch * m.channels;
                let mut p_mat = vec![0.0f32; rows * ppc];
                for bi_ in 0..b {
                    for gy in 0..g {
                        for gx in 0..g {
                            let r = (bi_ * g + gy) * g + gx;
                            let dst = &mut p_mat[r * ppc..(r + 1) * ppc];
                            let mut k = 0;
                            for py in 0..m.patch {
                                for pxi in 0..m.patch {
                                    for c in 0..m.channels {
                                        let yy = gy * m.patch + py;
                                        let xx = gx * m.patch + pxi;
                                        dst[k] = px[((bi_ * m.img + yy) * m.img + xx) * 3 + c];
                                        k += 1;
                                    }
                                }
                            }
                        }
                    }
                }
                h = par::matmul_f32(&p_mat, w.get(*emb)?, rows, ppc, d);
                let pe = w.get(*pos)?;
                for r in 0..rows {
                    let row = &mut h[r * d..(r + 1) * d];
                    let pe_row = &pe[(r % t) * d..(r % t + 1) * d];
                    add_into(row, pe_row);
                }
                patch = Some(p_mat);
            }
        }
        // Cross-token information flow (attention stand-in).
        h = mix_fwd(self.mix, &h, b, t, d);

        let mut block_tapes = Vec::with_capacity(self.blocks.len());
        for blk in &self.blocks {
            let h_in = h;
            let mut uq = par::matmul_f32(&h_in, w.get(blk.wq)?, rows, d, d);
            add_bias_rows(&mut uq, w.get(blk.bq)?, rows, d);
            let aq = relu(&uq);
            let mut h_mid = h_in.clone();
            add_into(&mut h_mid, &aq);
            let mut uv = par::matmul_f32(&h_mid, w.get(blk.wv)?, rows, d, d);
            add_bias_rows(&mut uv, w.get(blk.bv)?, rows, d);
            let av = relu(&uv);
            let mut h_out = h_mid.clone();
            add_into(&mut h_out, &av);
            let (mut z, mut a3) = (None, None);
            h = if let Some((di, ui)) = blk.adpt {
                let dmat = adapt[di].as_f32()?;
                let umat = adapt[ui].as_f32()?;
                let mw = adapt[di].shape[1];
                let zz = par::matmul_f32(&h_out, dmat, rows, d, mw);
                let aa = relu(&zz);
                let up = par::matmul_f32(&aa, umat, rows, mw, d);
                let mut hf = h_out.clone();
                add_into(&mut hf, &up);
                z = Some(zz);
                a3 = Some(aa);
                hf
            } else {
                h_out.clone()
            };
            block_tapes.push(BlockTape { h_in, uq, h_mid, uv, h_out, z, a3 });
        }
        let h_last = h;

        // --- head ---------------------------------------------------------
        let (head_rows, pooled, head_in): (usize, Option<Vec<f32>>, Vec<f32>) =
            match self.loss {
                Loss::Lm => (rows, None, h_last.clone()),
                _ => {
                    let mut p = vec![0.0f32; b * d];
                    for r in 0..rows {
                        let dst = &mut p[(r / t) * d..(r / t + 1) * d];
                        let src = &h_last[r * d..(r + 1) * d];
                        for j in 0..d {
                            dst[j] += src[j] / t as f32;
                        }
                    }
                    (b, Some(p.clone()), p)
                }
            };
        let classes = head_b.len();
        let mut logits = par::matmul_f32(&head_in, head_w, head_rows, d, classes);
        add_bias_rows(&mut logits, head_b, head_rows, classes);

        // --- loss ---------------------------------------------------------
        let (loss, dlogits, logits_t) = match self.loss {
            Loss::Ce => {
                let y = get_batch("y")?.as_i32()?;
                let (l, dl) = softmax_ce(&logits, b, classes, y, None)?;
                (l, dl, Tensor::f32(&[b, classes], logits))
            }
            Loss::Mse => {
                let y = get_batch("y")?.as_f32()?;
                let (l, dl) = mse(&logits, y);
                (l, dl, Tensor::f32(&[b, 1], logits))
            }
            Loss::Lm => {
                let y = get_batch("y")?.as_i32()?;
                let mask = get_batch("mask")?.as_f32()?;
                let (l, dl) = softmax_ce(&logits, rows, classes, y, Some(mask))?;
                (l, dl, Tensor::f32(&[b, t, classes], logits))
            }
            Loss::MseImg => unreachable!("mseimg is an mlp-trunk loss"),
        };

        let tape = want_tape.then_some(Tape {
            rows,
            toks,
            patch,
            blocks: block_tapes,
            h_last,
            pooled,
            x: None,
            u1: None,
            a1: None,
            u2: None,
            a2: None,
            z: None,
            a3: None,
            head_in,
            dlogits,
        });
        Ok(Fwd { loss, logits: logits_t, tape })
    }

    /// mlp / denoiser trunk.
    #[allow(clippy::too_many_arguments)]
    fn forward_mlp(
        &self,
        idx: &MlpIdx,
        w: &Weights,
        adapt: &[Tensor],
        batch: &HashMap<String, Tensor>,
        head_w: &[f32],
        head_b: &[f32],
        want_tape: bool,
    ) -> Result<Fwd> {
        let m = self.model;
        let b = m.batch;
        let in_dim = if m.kind == "mlp" { 2 } else { m.pix() };
        let h = m.hidden;
        let x_t = batch.get("x").ok_or_else(|| anyhow!("batch missing tensor 'x'"))?;
        anyhow::ensure!(
            x_t.shape == [b, in_dim],
            "batch 'x' shape {:?}, model wants [{b}, {in_dim}]",
            x_t.shape
        );
        let x = x_t.as_f32()?.to_vec();
        let mut u1 = par::matmul_f32(&x, w.get(idx.in_w)?, b, in_dim, h);
        add_bias_rows(&mut u1, w.get(idx.in_b)?, b, h);
        let a1 = relu(&u1);
        let mut u2 = par::matmul_f32(&a1, w.get(idx.hid_w)?, b, h, h);
        add_bias_rows(&mut u2, w.get(idx.hid_b)?, b, h);
        let a2 = relu(&u2);
        let (mut z, mut a3) = (None, None);
        let head_in: Vec<f32> = if let Some((di, ui)) = idx.adpt {
            let dmat = adapt[di].as_f32()?;
            let umat = adapt[ui].as_f32()?;
            let mw = adapt[di].shape[1];
            let zz = par::matmul_f32(&a2, dmat, b, h, mw);
            let aa = relu(&zz);
            let up = par::matmul_f32(&aa, umat, b, mw, h);
            let mut hf = a2.clone();
            add_into(&mut hf, &up);
            z = Some(zz);
            a3 = Some(aa);
            hf
        } else {
            a2.clone()
        };
        let out_dim = head_b.len();
        let mut logits = par::matmul_f32(&head_in, head_w, b, h, out_dim);
        add_bias_rows(&mut logits, head_b, b, out_dim);

        let (loss, dlogits, logits_t) = match self.loss {
            Loss::Ce => {
                let y = batch.get("y").ok_or_else(|| anyhow!("batch missing 'y'"))?.as_i32()?;
                let (l, dl) = softmax_ce(&logits, b, out_dim, y, None)?;
                (l, dl, Tensor::f32(&[b, out_dim], logits))
            }
            Loss::MseImg => {
                let y = batch.get("y").ok_or_else(|| anyhow!("batch missing 'y'"))?.as_f32()?;
                let (l, dl) = mse(&logits, y);
                (l, dl, Tensor::f32(&[b, out_dim], logits))
            }
            other => bail!("mlp trunk does not support loss {other:?}"),
        };
        let tape = want_tape.then_some(Tape {
            rows: b,
            toks: None,
            patch: None,
            blocks: Vec::new(),
            h_last: Vec::new(),
            pooled: None,
            x: Some(x),
            u1: Some(u1),
            a1: Some(a1),
            u2: Some(u2),
            a2: Some(a2),
            z,
            a3,
            head_in,
            dlogits,
        });
        Ok(Fwd { loss, logits: logits_t, tape })
    }

    /// Backward pass over a tape: fill `Grads` for everything in `needs`
    /// plus the Houlsby adapter tensors (always trained when present).
    pub fn backward(
        &self,
        w: &Weights,
        adapt: &[Tensor],
        tape: &Tape,
        needs: &Needs,
    ) -> Result<Grads> {
        let mut grads = Grads::default();
        let m = self.model;
        let head_w_t = self.tensor_at(self.head_w, w.base, adapt).clone();
        let head_w: &[f32] = match self.head_w {
            Loc::Base(i) => w.get(i)?,
            Loc::Adapt(_) => head_w_t.as_f32()?,
        };
        let d_in = m.head_in();
        let classes = head_w_t.shape[1];
        let head_rows = tape.head_in.len() / d_in;

        // --- head ---------------------------------------------------------
        if needs.head {
            let dw = weight_grad(&tape.head_in, &tape.dlogits, head_rows, d_in, classes);
            let db = colsum(&tape.dlogits, head_rows, classes);
            if let Loc::Adapt(i) = self.head_w {
                grads.adapt.insert(i, dw);
            }
            if let Loc::Adapt(i) = self.head_b {
                grads.adapt.insert(i, db);
            }
        } else if let Loc::Base(i) = self.head_w {
            // ff on a frozen-head artifact never happens (ff trains the
            // head as adapt), but a dense delta on head.* would land here.
            if needs.w.contains(&i) {
                grads
                    .base
                    .insert(i, weight_grad(&tape.head_in, &tape.dlogits, head_rows, d_in, classes));
            }
        }
        let mut dhead_in =
            par::matmul_f32(&tape.dlogits, &transpose(head_w, d_in, classes), head_rows, classes, d_in);
        if let (Loc::Base(i), false) = (self.head_b, needs.head) {
            if needs.b.contains(&i) {
                grads.base.insert(i, colsum(&tape.dlogits, head_rows, classes));
            }
        }

        if let Some(idx) = &self.mlp {
            return self.backward_mlp(idx, w, adapt, tape, needs, grads, dhead_in);
        }

        // --- transformer trunk -------------------------------------------
        let (t, d) = (m.tokens(), m.d);
        let rows = tape.rows;
        // un-pool (ce/mse) or pass through (lm)
        let mut dh: Vec<f32> = if tape.pooled.is_some() {
            let mut v = vec![0.0f32; rows * d];
            for r in 0..rows {
                let src = &dhead_in[(r / t) * d..(r / t + 1) * d];
                let dst = &mut v[r * d..(r + 1) * d];
                for j in 0..d {
                    dst[j] = src[j] / t as f32;
                }
            }
            v
        } else {
            std::mem::take(&mut dhead_in)
        };

        for (blk, bt) in self.blocks.iter().zip(&tape.blocks).rev() {
            // Houlsby adapter: h = h_out + relu(h_out·D)·U
            let dh_out: Vec<f32> = if let Some((di, ui)) = blk.adpt {
                let dmat = adapt[di].as_f32()?;
                let umat = adapt[ui].as_f32()?;
                let mw = adapt[di].shape[1];
                let (z, a3) = (
                    bt.z.as_ref().expect("adapter tape missing z"),
                    bt.a3.as_ref().expect("adapter tape missing a3"),
                );
                let du = weight_grad(a3, &dh, rows, mw, d);
                let da3 = par::matmul_f32(&dh, &transpose(umat, mw, d), rows, d, mw);
                let dz = relu_bwd(&da3, z);
                let dd = weight_grad(&bt.h_out, &dz, rows, d, mw);
                let mut out = dh.clone();
                add_into(&mut out, &par::matmul_f32(&dz, &transpose(dmat, d, mw), rows, mw, d));
                grads.adapt.insert(di, dd);
                grads.adapt.insert(ui, du);
                out
            } else {
                dh
            };
            // v sub-block
            let duv = relu_bwd(&dh_out, &bt.uv);
            if needs.w.contains(&blk.wv) {
                grads.base.insert(blk.wv, weight_grad(&bt.h_mid, &duv, rows, d, d));
            }
            if needs.b.contains(&blk.bv) {
                grads.base.insert(blk.bv, colsum(&duv, rows, d));
            }
            let mut dh_mid = dh_out;
            add_into(&mut dh_mid, &par::matmul_f32(&duv, &transpose(w.get(blk.wv)?, d, d), rows, d, d));
            // q sub-block
            let duq = relu_bwd(&dh_mid, &bt.uq);
            if needs.w.contains(&blk.wq) {
                grads.base.insert(blk.wq, weight_grad(&bt.h_in, &duq, rows, d, d));
            }
            if needs.b.contains(&blk.bq) {
                grads.base.insert(blk.bq, colsum(&duq, rows, d));
            }
            let mut dh_in = dh_mid;
            add_into(&mut dh_in, &par::matmul_f32(&duq, &transpose(w.get(blk.wq)?, d, d), rows, d, d));
            dh = dh_in;
        }
        // back through the cross-token mixing (exact transpose)
        dh = mix_bwd(self.mix, &dh, rows / t, t, d);

        // --- embedding grads (ff only) -----------------------------------
        match self.embed.as_ref().expect("transformer net has an embedding") {
            Embed::Tokens { tok, pos } => {
                if needs.w.contains(tok) {
                    let toks = tape.toks.as_ref().expect("token tape missing");
                    let mut dte = vec![0.0f32; m.vocab * d];
                    for r in 0..rows {
                        let dst = &mut dte[toks[r] * d..(toks[r] + 1) * d];
                        add_into(dst, &dh[r * d..(r + 1) * d]);
                    }
                    grads.base.insert(*tok, dte);
                }
                if needs.w.contains(pos) {
                    let mut dpe = vec![0.0f32; t * d];
                    for r in 0..rows {
                        let dst = &mut dpe[(r % t) * d..(r % t + 1) * d];
                        add_into(dst, &dh[r * d..(r + 1) * d]);
                    }
                    grads.base.insert(*pos, dpe);
                }
            }
            Embed::Patch { emb, pos } => {
                if needs.w.contains(emb) {
                    let p = tape.patch.as_ref().expect("patch tape missing");
                    let ppc = m.patch * m.patch * m.channels;
                    grads.base.insert(*emb, weight_grad(p, &dh, rows, ppc, d));
                }
                if needs.w.contains(pos) {
                    let mut dpe = vec![0.0f32; t * d];
                    for r in 0..rows {
                        let dst = &mut dpe[(r % t) * d..(r % t + 1) * d];
                        add_into(dst, &dh[r * d..(r + 1) * d]);
                    }
                    grads.base.insert(*pos, dpe);
                }
            }
        }
        Ok(grads)
    }

    #[allow(clippy::too_many_arguments)]
    fn backward_mlp(
        &self,
        idx: &MlpIdx,
        w: &Weights,
        adapt: &[Tensor],
        tape: &Tape,
        needs: &Needs,
        mut grads: Grads,
        dhead_in: Vec<f32>,
    ) -> Result<Grads> {
        let m = self.model;
        let b = tape.rows;
        let in_dim = if m.kind == "mlp" { 2 } else { m.pix() };
        let h = m.hidden;
        let (x, u1, a1, u2, a2) = (
            tape.x.as_ref().expect("mlp tape missing x"),
            tape.u1.as_ref().expect("mlp tape missing u1"),
            tape.a1.as_ref().expect("mlp tape missing a1"),
            tape.u2.as_ref().expect("mlp tape missing u2"),
            tape.a2.as_ref().expect("mlp tape missing a2"),
        );
        // adapter after the hidden layer
        let da2: Vec<f32> = if let Some((di, ui)) = idx.adpt {
            let dmat = adapt[di].as_f32()?;
            let umat = adapt[ui].as_f32()?;
            let mw = adapt[di].shape[1];
            let (z, a3) = (
                tape.z.as_ref().expect("adapter tape missing z"),
                tape.a3.as_ref().expect("adapter tape missing a3"),
            );
            let du = weight_grad(a3, &dhead_in, b, mw, h);
            let da3 = par::matmul_f32(&dhead_in, &transpose(umat, mw, h), b, h, mw);
            let dz = relu_bwd(&da3, z);
            let dd = weight_grad(a2, &dz, b, h, mw);
            let mut out = dhead_in.clone();
            add_into(&mut out, &par::matmul_f32(&dz, &transpose(dmat, h, mw), b, mw, h));
            grads.adapt.insert(di, dd);
            grads.adapt.insert(ui, du);
            out
        } else {
            dhead_in
        };
        let du2 = relu_bwd(&da2, u2);
        if needs.w.contains(&idx.hid_w) {
            grads.base.insert(idx.hid_w, weight_grad(a1, &du2, b, h, h));
        }
        if needs.b.contains(&idx.hid_b) {
            grads.base.insert(idx.hid_b, colsum(&du2, b, h));
        }
        let da1 = par::matmul_f32(&du2, &transpose(w.get(idx.hid_w)?, h, h), b, h, h);
        let du1 = relu_bwd(&da1, u1);
        if needs.w.contains(&idx.in_w) {
            grads.base.insert(idx.in_w, weight_grad(x, &du1, b, in_dim, h));
        }
        if needs.b.contains(&idx.in_b) {
            grads.base.insert(idx.in_b, colsum(&du1, b, h));
        }
        Ok(grads)
    }
}

/// Seeded init of one adapt tensor (trainable method/head tensors).
/// Keyed by (artifact, tensor name) so re-runs are bitwise identical and
/// init order never matters.
pub fn init_adapt_tensor(
    meta_name: &str,
    tm: &crate::runtime::artifact::TensorMeta,
    seed: i64,
    statics_entries: Option<&Tensor>,
) -> Result<Tensor> {
    let mut rng = crate::tensor::rng::Rng::new(
        (seed as u64) ^ 0xADA7_0001 ^ zoo::fnv64(meta_name) ^ zoo::fnv64(&tm.name),
    );
    let name = tm.name.as_str();
    // Frozen integer DCT locations: copied from the shared entry matrix.
    if tm.dtype == "i32" {
        let e = statics_entries
            .ok_or_else(|| anyhow!("adapt tensor '{name}' needs the 'entries' static"))?;
        anyhow::ensure!(
            e.shape == tm.shape,
            "entries shape {:?} vs adapt '{name}' shape {:?}",
            e.shape,
            tm.shape
        );
        return Ok(e.clone());
    }
    let t = if name == "head.w" {
        Tensor::f32(&tm.shape, rng.normal_vec(tm.numel(), (2.0 / tm.shape[0] as f32).sqrt()))
    } else if name.starts_with("lora.") && name.ends_with(".a") {
        // Kaiming-style A, zero B: ΔW starts at 0 (LoRA's init recipe).
        Tensor::f32(&tm.shape, rng.normal_vec(tm.numel(), (1.0 / tm.shape[1] as f32).sqrt()))
    } else if name.starts_with("adpt.") && name.ends_with(".d") {
        Tensor::f32(&tm.shape, rng.normal_vec(tm.numel(), (2.0 / tm.shape[0] as f32).sqrt()))
    } else if name.starts_with("circ.") && name.ends_with(".g") {
        // Unit gains with zero circulant column: ΔW = 0 but ∂L/∂c ≠ 0.
        Tensor::f32(&tm.shape, vec![1.0; tm.numel()])
    } else {
        // Spectral coefficients, dense/bias deltas, lora B, adapter U,
        // head bias: zero — every method starts at ΔW = 0.
        Tensor::zeros(&tm.shape)
    };
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_ce_gradient_sums_to_zero_per_row() {
        let logits = vec![0.3, -0.2, 1.1, 0.0, 0.5, -0.5];
        let (loss, dl) = softmax_ce(&logits, 2, 3, &[2, 0], None).unwrap();
        assert!(loss > 0.0);
        for r in 0..2 {
            let s: f32 = dl[r * 3..(r + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "row {r} grad sum {s}");
        }
    }

    #[test]
    fn softmax_ce_masked_rows_contribute_nothing() {
        let logits = vec![0.3, -0.2, 9.9, 9.9, 0.5, -0.5];
        let (_, dl) = softmax_ce(&logits, 3, 2, &[1, 0, 0], Some(&[1.0, 0.0, 1.0])).unwrap();
        assert!(dl[2] == 0.0 && dl[3] == 0.0, "masked row must have zero grad");
        let (l_all_masked, dl0) = softmax_ce(&logits, 3, 2, &[1, 0, 0], Some(&[0.0; 3])).unwrap();
        assert_eq!(l_all_masked, 0.0);
        assert!(dl0.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mse_matches_manual() {
        let (l, dl) = mse(&[1.0, 3.0], &[0.0, 1.0]);
        assert!((l - (1.0 + 4.0) / 2.0).abs() < 1e-6);
        assert!((dl[0] - 1.0).abs() < 1e-6 && (dl[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn mix_backward_is_exact_transpose() {
        // ⟨A·x, y⟩ = ⟨x, Aᵀ·y⟩ for the linear mixing map A = I + M.
        let (b, t, d) = (2usize, 5usize, 3usize);
        let mut rng = crate::tensor::rng::Rng::new(21);
        for mix in [Mix::Full, Mix::Causal, Mix::None] {
            let x = rng.normal_vec(b * t * d, 1.0);
            let y = rng.normal_vec(b * t * d, 1.0);
            let lhs: f64 = mix_fwd(mix, &x, b, t, d)
                .iter()
                .zip(&y)
                .map(|(&a, &v)| a as f64 * v as f64)
                .sum();
            let rhs: f64 = x
                .iter()
                .zip(&mix_bwd(mix, &y, b, t, d))
                .map(|(&a, &v)| a as f64 * v as f64)
                .sum();
            assert!((lhs - rhs).abs() < 1e-3, "{mix:?}: <Ax,y>={lhs} vs <x,Aᵀy>={rhs}");
        }
    }

    #[test]
    fn causal_mix_never_looks_ahead() {
        // Perturbing the last token must leave earlier positions bitwise
        // unchanged — the property greedy decoding relies on.
        let (b, t, d) = (1usize, 4usize, 2usize);
        let x0 = vec![0.5f32; b * t * d];
        let mut x = x0.clone();
        let base = mix_fwd(Mix::Causal, &x, b, t, d);
        x[(t - 1) * d] += 1.0;
        let bumped = mix_fwd(Mix::Causal, &x, b, t, d);
        for i in 0..(t - 1) * d {
            assert_eq!(base[i].to_bits(), bumped[i].to_bits(), "position {i} saw the future");
        }
        // ...and the full mix does mix: position 0 must change.
        let full_base = mix_fwd(Mix::Full, &x0, b, t, d);
        let full_bumped = mix_fwd(Mix::Full, &x, b, t, d);
        assert_ne!(full_base[0].to_bits(), full_bumped[0].to_bits());
    }

    #[test]
    fn transpose_and_weight_grad_shapes() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let t = transpose(&x, 2, 3);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        let dy = vec![1.0, 0.0, 0.0, 1.0]; // 2x2
        let dw = weight_grad(&x, &dy, 2, 3, 2);
        // dW = Xᵀ·dY: [[1,4],[2,5],[3,6]]
        assert_eq!(dw, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }
}
