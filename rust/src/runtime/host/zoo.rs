//! Host-engine model zoo: the Rust mirror of `python/compile/configs.py`.
//!
//! The XLA path learns its tensor-level ABI from `artifacts/*.meta.json`;
//! the host engine has no artifacts, so this module *synthesizes* the
//! same [`ArtifactMeta`] from an artifact name
//! (`"<model>__<method_tag>__<loss>"`, e.g. `enc_base__fourierft_n64__ce`)
//! and the static model table below. Everything downstream — statics
//! sampling, site-dims maps, adapter publishing, budget tables — consumes
//! the meta exactly as if an artifact registry had produced it.
//!
//! Base (backbone) tensors are initialized per *name* with a seeded,
//! order-independent PRNG stream, so the backbone init is identical
//! across every artifact of a model — the property the cached
//! `runs/bases/*.base` checkpoints rely on.

use crate::adapter::method;
use crate::runtime::artifact::{ArtifactMeta, MethodMeta, ModelMeta, TensorMeta};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::{anyhow, bail, Result};

/// Architecture of one sim model (mirrors `configs.ModelCfg`).
#[derive(Debug, Clone, Copy)]
pub struct ModelCfg {
    pub name: &'static str,
    pub kind: &'static str, // mlp | encoder | decoder | vit | denoiser
    pub d: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seqlen: usize,
    pub classes: usize,
    pub img: usize,
    pub patch: usize,
    pub channels: usize,
    pub hidden: usize,
    pub batch: usize,
}

impl ModelCfg {
    /// Sequence length seen by the transformer blocks.
    pub fn tokens(&self) -> usize {
        if self.kind == "vit" {
            (self.img / self.patch) * (self.img / self.patch)
        } else {
            self.seqlen
        }
    }

    /// Flattened pixels per image (denoiser input/output width).
    pub fn pix(&self) -> usize {
        self.img * self.img * self.channels
    }

    /// Width of the representation the task head consumes.
    pub fn head_in(&self) -> usize {
        match self.kind {
            "mlp" | "denoiser" => self.hidden,
            _ => self.d,
        }
    }

    fn is_transformer(&self) -> bool {
        matches!(self.kind, "encoder" | "decoder" | "vit")
    }
}

const DEF: ModelCfg = ModelCfg {
    name: "",
    kind: "",
    d: 128,
    layers: 4,
    vocab: 1000,
    seqlen: 32,
    classes: 4,
    img: 32,
    patch: 4,
    channels: 3,
    hidden: 64,
    batch: 32,
};

/// The sim model zoo (same names/dims as `configs.py`).
pub const MODELS: &[ModelCfg] = &[
    ModelCfg { name: "mlp", kind: "mlp", hidden: 64, classes: 8, batch: 64, ..DEF },
    ModelCfg { name: "enc_base", kind: "encoder", d: 128, layers: 4, classes: 3, ..DEF },
    ModelCfg { name: "enc_large", kind: "encoder", d: 192, layers: 6, classes: 3, ..DEF },
    ModelCfg { name: "dec_med", kind: "decoder", d: 128, layers: 4, seqlen: 48, ..DEF },
    ModelCfg { name: "dec_large", kind: "decoder", d: 192, layers: 6, seqlen: 48, ..DEF },
    ModelCfg { name: "denoiser", kind: "denoiser", hidden: 256, img: 16, ..DEF },
    ModelCfg { name: "vit_base", kind: "vit", d: 128, layers: 4, classes: 200, ..DEF },
    ModelCfg { name: "vit_large", kind: "vit", d: 192, layers: 6, classes: 200, ..DEF },
];

pub fn model(name: &str) -> Result<&'static ModelCfg> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow!("unknown host model '{name}' (known: mlp, enc_base, enc_large, dec_med, dec_large, denoiser, vit_base, vit_large)"))
}

/// One parsed PEFT method tag (mirrors `configs.MethodCfg`).
#[derive(Debug, Clone)]
pub struct MethodSpec {
    /// Base method name: ff | lp | bitfit | adapter | lora | fourierft |
    /// loca | circulant.
    pub name: String,
    pub r: usize,
    pub n: usize,
    pub m: usize,
    /// Train the task head (`_fh` tags freeze a random head — the
    /// Figure 7 expressivity protocol).
    pub head: bool,
}

/// Parse a method tag like `fourierft_n64`, `lora_r8_fh`, `adapter_m8`.
pub fn parse_tag(tag: &str) -> Result<MethodSpec> {
    let (core, head) = match tag.strip_suffix("_fh") {
        Some(rest) => (rest, false),
        None => (tag, true),
    };
    let mut spec = MethodSpec { name: core.to_string(), r: 0, n: 0, m: 0, head };
    let parse_num = |s: &str, what: &str| -> Result<usize> {
        s.parse().map_err(|_| anyhow!("bad {what} in method tag '{tag}'"))
    };
    if let Some(rest) = core.strip_prefix("lora_r") {
        spec.name = "lora".into();
        spec.r = parse_num(rest, "rank")?;
    } else if let Some(rest) = core.strip_prefix("fourierft_n") {
        spec.name = "fourierft".into();
        spec.n = parse_num(rest, "n")?;
    } else if let Some(rest) = core.strip_prefix("loca_n") {
        spec.name = "loca".into();
        spec.n = parse_num(rest, "n")?;
    } else if let Some(rest) = core.strip_prefix("adapter_m") {
        spec.name = "adapter".into();
        spec.m = parse_num(rest, "m")?;
    } else if let Some(rest) = core.strip_prefix("randbasis_n") {
        spec.name = "randbasis".into();
        spec.n = parse_num(rest, "n")?;
    } else if let Some(rest) = core.strip_prefix("orthobasis_n") {
        spec.name = "orthobasis".into();
        spec.n = parse_num(rest, "n")?;
    } else if !matches!(core, "ff" | "lp" | "bitfit" | "circulant") {
        bail!("unknown method tag '{tag}'");
    }
    Ok(spec)
}

/// One parsed artifact name.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub model: &'static ModelCfg,
    pub method: MethodSpec,
    pub loss: String,
}

/// Parse `"<model>__<method_tag>__<loss>"` and reject combinations the
/// host engine cannot run (the `randbasis`/`orthobasis` Table-6 ablations
/// are lowered only as XLA artifacts).
pub fn parse(artifact: &str) -> Result<Parsed> {
    let parts: Vec<&str> = artifact.split("__").collect();
    if parts.len() != 3 {
        bail!("artifact name '{artifact}' is not <model>__<method>__<loss>");
    }
    let model = model(parts[0])?;
    let method = parse_tag(parts[1])?;
    if matches!(method.name.as_str(), "randbasis" | "orthobasis") {
        bail!(
            "method '{}' is an XLA-only ablation (random basis statics); \
             use --engine xla for artifact '{artifact}'",
            method.name
        );
    }
    let loss = parts[2].to_string();
    if !matches!(loss.as_str(), "ce" | "mse" | "lm" | "mlm" | "mseimg") {
        bail!("unknown loss '{loss}' in artifact '{artifact}'");
    }
    match (model.kind, loss.as_str()) {
        ("mlp", "ce")
        | ("encoder", "ce" | "mse" | "mlm")
        | ("decoder", "lm")
        | ("vit", "ce")
        | ("denoiser", "mseimg") => {}
        (kind, l) => bail!("host engine has no {kind} model with loss '{l}'"),
    }
    Ok(Parsed { model, method, loss })
}

/// Backbone tensor schema for one model (name order is the `.base`
/// checkpoint order).
pub fn base_schema(m: &ModelCfg) -> Vec<TensorMeta> {
    let t = |name: String, shape: Vec<usize>| TensorMeta {
        name,
        role: "base".into(),
        dtype: "f32".into(),
        shape,
    };
    let mut out = Vec::new();
    match m.kind {
        "mlp" => {
            out.push(t("in.w".into(), vec![2, m.hidden]));
            out.push(t("in.b".into(), vec![m.hidden]));
            out.push(t("hid.w".into(), vec![m.hidden, m.hidden]));
            out.push(t("hid.b".into(), vec![m.hidden]));
        }
        "denoiser" => {
            out.push(t("in.w".into(), vec![m.pix(), m.hidden]));
            out.push(t("in.b".into(), vec![m.hidden]));
            out.push(t("hid.w".into(), vec![m.hidden, m.hidden]));
            out.push(t("hid.b".into(), vec![m.hidden]));
        }
        "encoder" | "decoder" => {
            out.push(t("tok_emb".into(), vec![m.vocab, m.d]));
            out.push(t("pos_emb".into(), vec![m.seqlen, m.d]));
            push_blocks(&mut out, m);
        }
        "vit" => {
            out.push(t("patch_emb".into(), vec![m.patch * m.patch * m.channels, m.d]));
            out.push(t("pos_emb".into(), vec![m.tokens(), m.d]));
            push_blocks(&mut out, m);
        }
        other => unreachable!("unknown model kind {other}"),
    }
    out
}

fn push_blocks(out: &mut Vec<TensorMeta>, m: &ModelCfg) {
    for i in 0..m.layers {
        for (suffix, shape) in [
            ("wq", vec![m.d, m.d]),
            ("bq", vec![m.d]),
            ("wv", vec![m.d, m.d]),
            ("bv", vec![m.d]),
        ] {
            out.push(TensorMeta {
                name: format!("blk{i}.{suffix}"),
                role: "base".into(),
                dtype: "f32".into(),
                shape,
            });
        }
    }
}

/// Task-head (w, b) shapes for (model, loss).
pub fn head_shapes(m: &ModelCfg, loss: &str) -> (Vec<usize>, Vec<usize>) {
    let d_in = m.head_in();
    let out = match loss {
        "ce" => m.classes,
        "mse" => 1,
        "lm" | "mlm" => m.vocab,
        "mseimg" => m.pix(),
        other => unreachable!("unknown loss {other}"),
    };
    (vec![d_in, out], vec![out])
}

/// The 2-D weight sites ΔW methods adapt (paper: the q/v projections; the
/// single hidden layer for mlp/denoiser).
pub fn adapted_sites(m: &ModelCfg) -> Vec<String> {
    if m.is_transformer() {
        (0..m.layers)
            .flat_map(|i| [format!("blk{i}.wq"), format!("blk{i}.wv")])
            .collect()
    } else {
        vec!["hid.w".to_string()]
    }
}

/// The bias sites `bitfit` adapts.
pub fn bias_sites(m: &ModelCfg) -> Vec<String> {
    if m.is_transformer() {
        (0..m.layers)
            .flat_map(|i| [format!("blk{i}.bq"), format!("blk{i}.bv")])
            .collect()
    } else {
        vec!["hid.b".to_string()]
    }
}

/// Houlsby-adapter insertion points (one bottleneck per block / after the
/// hidden layer), named by prefix: `adpt.<site>.{d,u}`.
pub fn adapter_sites(m: &ModelCfg) -> Vec<String> {
    if m.is_transformer() {
        (0..m.layers).map(|i| format!("blk{i}")).collect()
    } else {
        vec!["hid".to_string()]
    }
}

/// The batch tensors (name, dtype, shape) for (model, loss).
fn batch_schema(m: &ModelCfg, loss: &str) -> Vec<TensorMeta> {
    let t = |name: &str, dtype: &str, shape: Vec<usize>| TensorMeta {
        name: name.into(),
        role: "batch".into(),
        dtype: dtype.into(),
        shape,
    };
    let b = m.batch;
    match (m.kind, loss) {
        ("mlp", _) => vec![t("x", "f32", vec![b, 2]), t("y", "i32", vec![b])],
        ("denoiser", _) => {
            vec![t("x", "f32", vec![b, m.pix()]), t("y", "f32", vec![b, m.pix()])]
        }
        ("vit", _) => {
            vec![t("x", "f32", vec![b, m.img, m.img, 3]), t("y", "i32", vec![b])]
        }
        (_, "mse") => vec![t("x", "i32", vec![b, m.seqlen]), t("y", "f32", vec![b])],
        (_, "ce") => vec![t("x", "i32", vec![b, m.seqlen]), t("y", "i32", vec![b])],
        (_, "lm" | "mlm") => vec![
            t("x", "i32", vec![b, m.seqlen]),
            t("y", "i32", vec![b, m.seqlen]),
            t("mask", "f32", vec![b, m.seqlen]),
        ],
        (kind, l) => unreachable!("no batch schema for {kind}/{l}"),
    }
}

/// Logits output shape for (model, loss).
fn logits_shape(m: &ModelCfg, loss: &str) -> Vec<usize> {
    match loss {
        "ce" => vec![m.batch, m.classes],
        "mse" => vec![m.batch, 1],
        "lm" | "mlm" => vec![m.batch, m.seqlen, m.vocab],
        "mseimg" => vec![m.batch, m.pix()],
        other => unreachable!("unknown loss {other}"),
    }
}

/// Adapt-tensor schema for (model, method, loss): the method's per-site
/// tensors (named via the registry's legacy naming so saved adapters
/// classify on publish), plus the task head when it is trained.
pub fn adapt_schema(p: &Parsed) -> Result<Vec<TensorMeta>> {
    let m = p.model;
    let t = |name: String, dtype: &str, shape: Vec<usize>| TensorMeta {
        name,
        role: "adapt".into(),
        dtype: dtype.into(),
        shape,
    };
    let mut out = Vec::new();
    match p.method.name.as_str() {
        "fourierft" => {
            let reg = method::get("fourierft")?;
            for site in adapted_sites(m) {
                out.push(t(reg.tensor_name(&site, "coef"), "f32", vec![p.method.n]));
            }
        }
        "loca" => {
            let reg = method::get("loca")?;
            for site in adapted_sites(m) {
                out.push(t(reg.tensor_name(&site, "coef"), "f32", vec![p.method.n]));
                out.push(t(reg.tensor_name(&site, "locs"), "i32", vec![2, p.method.n]));
            }
        }
        "lora" => {
            let reg = method::get("lora")?;
            let (d1, d2) = site_dims_of(m);
            for site in adapted_sites(m) {
                out.push(t(reg.tensor_name(&site, "a"), "f32", vec![p.method.r, d2]));
                out.push(t(reg.tensor_name(&site, "b"), "f32", vec![d1, p.method.r]));
            }
        }
        "circulant" => {
            let reg = method::get("circulant")?;
            let (d1, _) = site_dims_of(m);
            for site in adapted_sites(m) {
                out.push(t(reg.tensor_name(&site, "circ"), "f32", vec![d1]));
                out.push(t(reg.tensor_name(&site, "diag"), "f32", vec![d1]));
            }
        }
        "bitfit" => {
            let reg = method::get("bitfit")?;
            let width = site_width(m);
            for site in bias_sites(m) {
                out.push(t(reg.tensor_name(&site, "delta"), "f32", vec![width]));
            }
        }
        "ff" => {
            let reg = method::get("dense")?;
            for bt in base_schema(m) {
                out.push(t(reg.tensor_name(&bt.name, "delta"), "f32", bt.shape));
            }
        }
        "adapter" => {
            let w = m.head_in();
            for site in adapter_sites(m) {
                out.push(t(format!("adpt.{site}.d"), "f32", vec![w, p.method.m]));
                out.push(t(format!("adpt.{site}.u"), "f32", vec![p.method.m, w]));
            }
        }
        "lp" => {}
        other => bail!("host engine cannot train method '{other}'"),
    }
    if p.method.head {
        let (hw, hb) = head_shapes(m, &p.loss);
        out.push(t("head.w".into(), "f32", hw));
        out.push(t("head.b".into(), "f32", hb));
    }
    Ok(out)
}

/// (d1, d2) of the adapted weight sites (square within every zoo model).
fn site_dims_of(m: &ModelCfg) -> (usize, usize) {
    let w = site_width(m);
    (w, w)
}

fn site_width(m: &ModelCfg) -> usize {
    if m.is_transformer() {
        m.d
    } else {
        m.hidden
    }
}

/// Synthesize the full [`ArtifactMeta`] for an artifact name.
pub fn artifact_meta(artifact: &str) -> Result<ArtifactMeta> {
    let p = parse(artifact)?;
    let m = p.model;
    let mut inputs = base_schema(m);
    // A frozen head (lp never freezes; `_fh` tags do) lives with the base
    // tensors: present in the forward pass, untouched by the optimizer.
    if !p.method.head {
        let (hw, hb) = head_shapes(m, &p.loss);
        inputs.push(TensorMeta { name: "head.w".into(), role: "base".into(), dtype: "f32".into(), shape: hw });
        inputs.push(TensorMeta { name: "head.b".into(), role: "base".into(), dtype: "f32".into(), shape: hb });
    }
    let adapt = adapt_schema(&p)?;
    let trainable: usize =
        adapt.iter().filter(|t| t.dtype == "f32").map(|t| t.numel()).sum();
    let trainable_ex_head: usize = adapt
        .iter()
        .filter(|t| t.dtype == "f32" && !t.name.starts_with("head."))
        .map(|t| t.numel())
        .sum();
    inputs.extend(adapt);
    if matches!(p.method.name.as_str(), "fourierft" | "loca") {
        inputs.push(TensorMeta {
            name: "entries".into(),
            role: "static".into(),
            dtype: "i32".into(),
            shape: vec![2, p.method.n],
        });
    }
    for s in ["step", "lr", "lr_head", "wd", "scaling"] {
        inputs.push(TensorMeta { name: s.into(), role: "scalar".into(), dtype: "f32".into(), shape: vec![] });
    }
    inputs.extend(batch_schema(m, &p.loss));

    let outputs = vec![
        TensorMeta { name: "loss".into(), role: "loss".into(), dtype: "f32".into(), shape: vec![] },
        TensorMeta {
            name: "logits".into(),
            role: "logits".into(),
            dtype: "f32".into(),
            shape: logits_shape(m, &p.loss),
        },
    ];

    Ok(ArtifactMeta {
        name: artifact.to_string(),
        loss: p.loss.clone(),
        model: ModelMeta {
            name: m.name.into(),
            kind: m.kind.into(),
            d: m.d,
            layers: m.layers,
            vocab: m.vocab,
            seqlen: m.seqlen,
            classes: m.classes,
            batch: m.batch,
            img: m.img,
            patch: m.patch,
            channels: m.channels,
            hidden: m.hidden,
        },
        method: MethodMeta { name: p.method.name.clone(), r: p.method.r, n: p.method.n, m: p.method.m },
        inputs,
        outputs,
        step_hlo: String::new(),
        init_hlo: String::new(),
        trainable,
        trainable_ex_head,
    })
}

/// FNV-1a, for name-stable per-tensor init streams (the crate-wide name
/// hash, re-exported here because every host init call site keys on it).
pub use crate::util::fnv64;

/// Seeded init of one base tensor, keyed by (model, tensor name) so the
/// stream is order-independent: every artifact of a model sees the same
/// backbone init, and frozen `_fh` heads are reproducible.
pub fn init_base_tensor(m: &ModelCfg, tm: &TensorMeta, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed ^ 0xBA5E_0001 ^ fnv64(m.name) ^ fnv64(&tm.name));
    let numel = tm.numel();
    // Biases start at zero.
    if tm.shape.len() == 1 && (tm.name.ends_with(".b") || tm.name.starts_with("blk")) {
        return Tensor::zeros(&tm.shape);
    }
    let std = match tm.name.as_str() {
        "tok_emb" => 0.5,
        "pos_emb" => 0.1,
        // Residual-branch weights scaled 1/sqrt(2L) (GPT-2 trick) so the
        // un-normalized trunk keeps activation variance bounded in depth.
        n if n.starts_with("blk") => {
            (2.0 / m.d as f32).sqrt() / (2.0 * m.layers as f32).sqrt()
        }
        // He init for plain fan-in layers (in.w, patch_emb, hid.w, head.w).
        _ => (2.0 / tm.shape[0] as f32).sqrt(),
    };
    Tensor::f32(&tm.shape, rng.normal_vec(numel, std))
}

/// Fresh seeded base tensors for every `role = "base"` input of `meta`
/// (backbone + any frozen head), in meta order.
pub fn init_base_for(meta: &ArtifactMeta, seed: u64) -> Result<Vec<Tensor>> {
    let m = model(&meta.model.name)?;
    Ok(meta.inputs_with_role("base").iter().map(|tm| init_base_tensor(m, tm, seed)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_known_artifacts() {
        for name in [
            "mlp__fourierft_n128__ce",
            "mlp__fourierft_n128_fh__ce",
            "enc_base__lora_r8__ce",
            "enc_base__ff__mlm",
            "enc_base__bitfit__ce",
            "enc_base__adapter_m8__ce",
            "enc_base__loca_n64__ce",
            "enc_base__circulant__ce",
            "dec_med__fourierft_n64__lm",
            "vit_base__lp__ce",
            "denoiser__ff__mseimg",
        ] {
            let meta = artifact_meta(name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(meta.name, name);
            assert!(meta.logits_shape().is_ok(), "{name} has no logits");
        }
    }

    #[test]
    fn rejects_xla_only_and_malformed() {
        assert!(artifact_meta("enc_base__randbasis_n64__ce").is_err());
        assert!(artifact_meta("enc_base__orthobasis_n64__ce").is_err());
        assert!(artifact_meta("nope__ff__ce").is_err());
        assert!(artifact_meta("enc_base__ff").is_err());
        assert!(artifact_meta("enc_base__ff__nolos").is_err());
        assert!(artifact_meta("mlp__ff__lm").is_err());
    }

    #[test]
    fn fh_moves_head_to_base() {
        let with = artifact_meta("mlp__fourierft_n128__ce").unwrap();
        let frozen = artifact_meta("mlp__fourierft_n128_fh__ce").unwrap();
        assert!(with.inputs_with_role("adapt").iter().any(|t| t.name == "head.w"));
        assert!(frozen.inputs_with_role("base").iter().any(|t| t.name == "head.w"));
        assert!(!frozen.inputs_with_role("adapt").iter().any(|t| t.name == "head.w"));
        // param parity with the Figure 7 protocol: n=128 at the single
        // adapted site, nothing else trainable when the head is frozen.
        assert_eq!(frozen.trainable, 128);
        assert_eq!(frozen.trainable_ex_head, 128);
    }

    #[test]
    fn loca_locations_are_not_counted_trainable() {
        let meta = artifact_meta("enc_base__loca_n64__ce").unwrap();
        // 8 sites x 64 coefficients + head (128*3 + 3); the i32 location
        // matrices are excluded.
        let head = 128 * 3 + 3;
        assert_eq!(meta.trainable, 8 * 64 + head);
        assert_eq!(meta.trainable_ex_head, 8 * 64);
    }

    #[test]
    fn base_init_is_name_stable_and_seeded() {
        let m = model("enc_base").unwrap();
        let schema = base_schema(m);
        let a = init_base_tensor(m, &schema[0], 0);
        let b = init_base_tensor(m, &schema[0], 0);
        assert_eq!(a, b);
        let c = init_base_tensor(m, &schema[0], 1);
        assert_ne!(a, c);
        // biases are zero
        let bias = schema.iter().find(|t| t.name == "blk0.bq").unwrap();
        assert!(init_base_tensor(m, bias, 0).as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn meta_site_dims_cover_adapted_sites() {
        let meta = artifact_meta("enc_base__fourierft_n64__ce").unwrap();
        let dims = meta.site_dims();
        for site in adapted_sites(model("enc_base").unwrap()) {
            assert_eq!(dims.get(&site), Some(&(128, 128)), "{site}");
        }
    }
}
