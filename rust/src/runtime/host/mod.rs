//! Pure-host training engine: the default-build [`StepEngine`] that makes
//! every sim-zoo fine-tune run with no XLA toolchain and no artifacts.
//!
//! * [`zoo`] — the Rust mirror of `python/compile/configs.py`: model
//!   table, method-tag parsing, synthesized [`ArtifactMeta`]s, seeded
//!   name-stable base init.
//! * [`model`] — forward + analytic backward for the two trunk families
//!   (mlp/denoiser, residual-mixer transformer), Adam-ready gradients.
//! * [`HostEngine`] — glues them behind the engine trait: effective
//!   weights `W_eff = W₀ + ΔW(θ)` are materialized through the adapter
//!   method registry, and method-parameter gradients come from each
//!   method's [`site_delta_grad`](crate::adapter::method::DeltaMethod::site_delta_grad)
//!   adjoint.
//!
//! # The spectral adjoint
//!
//! FourierFT's ΔW is *linear* in the n learned spectral coefficients:
//!
//! ```text
//! ΔW[p, q] = α/(d1·d2) · Σ_l c_l · cos(ω_l p + ν_l q)
//!          = (A(c) · B)[p, q]
//! ```
//!
//! with `A(c) = [Cu·diag(s) | −Su·diag(s)]`, `s = α·c/(d1·d2)`, and
//! `B = [Cv; Sv]` the cached twiddle tables of the forward
//! [`ReconstructPlan`](crate::fourier::ReconstructPlan) GEMM. By the chain
//! rule, with `G = ∂L/∂ΔW` flowing back from the trunk,
//!
//! ```text
//! ∂L/∂c_l = Σ_pq G[p,q] · ∂ΔW[p,q]/∂c_l
//!         = α/(d1·d2) · Σ_p ( Cu[p,l]·(G·Cvᵀ)[p,l] − Su[p,l]·(G·Svᵀ)[p,l] )
//! ```
//!
//! i.e. the **transpose of the same GEMM** — one `(d1×d2)·(d2×2n)`
//! product against `Bᵀ` followed by an O(d1·n) contraction, reusing the
//! twiddle tables the forward pass already built
//! ([`ReconstructPlan::coeff_grad`](crate::fourier::ReconstructPlan::coeff_grad)).
//! The same argument gives `loca` its n-column cosine adjoint (no sin
//! block), `lora` the usual two-GEMM rule `∂A = α·Bᵀ·G`, `∂B = α·G·Aᵀ`,
//! and `dense`/`bitfit`/`circulant` direct gathers. Finite-difference
//! validation for every 2-D method lives in `tests/host_engine.rs`
//! (≤ 1e-3 relative error).
//!
//! # Determinism
//!
//! Base and adapt inits are keyed by (seed, model/artifact, tensor name);
//! batches come from the seeded data generators; the blocked GEMM
//! computes each output element in a fixed order regardless of thread
//! count. A re-run with the same seed is therefore bitwise identical —
//! asserted in `tests/host_engine.rs`.

pub mod model;
pub mod zoo;

use super::artifact::ArtifactMeta;
use super::engine::{ParamSet, StepEngine, StepOut, StepScalars};
use crate::adapter::method::{self, DeltaMethod, ReconstructCtx, SiteSpec, SiteTensors};
use crate::fourier::plan;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// How one adapted site's ΔW (and its adjoint) is produced.
enum BindKind {
    /// FourierFT through the statics entry matrix + the process-wide
    /// plan cache (supports Eq. 5 biased entries, shares twiddle tables
    /// with serving). `coef` is the adapt index of the coefficient vec.
    Fourier { coef: usize },
    /// Any other registered method, via `site_delta` / `site_delta_grad`.
    /// The dispatch passes `ReconstructCtx { seed: 0, … }`: none of the
    /// generic built-ins reads the seed (loca stores its locations as a
    /// tensor precisely so it has no seed dependence), and a custom
    /// method that wants host training must follow the same rule — derive
    /// ΔW from stored tensors only, not from `ctx.seed`, or its served
    /// reconstruction (which uses the adapter file's seed) would silently
    /// diverge from what was trained.
    Generic { method: Arc<dyn DeltaMethod>, roles: Vec<(String, usize)> },
}

/// One adapted site: base tensor + method tensors + dims.
struct Binding {
    site: String,
    base: usize,
    d1: usize,
    d2: usize,
    kind: BindKind,
}

/// Pure-Rust step engine over the sim model zoo.
pub struct HostEngine {
    meta: ArtifactMeta,
    net: model::Net,
    bindings: Vec<Binding>,
    needs: model::Needs,
    adapt_names: Vec<String>,
    /// Position of the shared entry matrix in the statics group.
    entries_static: Option<usize>,
}

impl HostEngine {
    /// Build the engine for an artifact name (`model__method__loss`).
    pub fn from_artifact(artifact: &str) -> Result<HostEngine> {
        let parsed = zoo::parse(artifact)?;
        let meta = zoo::artifact_meta(artifact)?;
        let base_metas = meta.inputs_with_role("base");
        let adapt_metas = meta.inputs_with_role("adapt");
        let base_idx: HashMap<String, usize> =
            base_metas.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        let adapt_idx: HashMap<String, usize> =
            adapt_metas.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        let adapt_names: Vec<String> = adapt_metas.iter().map(|t| t.name.clone()).collect();
        let entries_static = meta
            .inputs_with_role("static")
            .iter()
            .position(|t| t.name == "entries");

        let net = model::Net::build(
            parsed.model,
            &parsed.loss,
            &base_idx,
            &adapt_idx,
            parsed.method.name == "adapter",
        )?;

        let site_dims = |name: &str| -> Result<(usize, usize)> {
            let i = *base_idx
                .get(name)
                .ok_or_else(|| anyhow!("adapted site '{name}' is not a base tensor"))?;
            let shape = &base_metas[i].shape;
            Ok((shape[0], shape.get(1).copied().unwrap_or(1)))
        };
        let adapt_of = |name: String| -> Result<usize> {
            adapt_idx
                .get(&name)
                .copied()
                .ok_or_else(|| anyhow!("missing adapt tensor '{name}'"))
        };

        let mut bindings = Vec::new();
        match parsed.method.name.as_str() {
            "fourierft" => {
                let reg = method::get("fourierft")?;
                for site in zoo::adapted_sites(parsed.model) {
                    let (d1, d2) = site_dims(&site)?;
                    bindings.push(Binding {
                        base: base_idx[&site],
                        d1,
                        d2,
                        kind: BindKind::Fourier {
                            coef: adapt_of(reg.tensor_name(&site, "coef"))?,
                        },
                        site,
                    });
                }
            }
            "loca" | "lora" | "circulant" => {
                let reg = method::get(&parsed.method.name)?;
                for site in zoo::adapted_sites(parsed.model) {
                    let (d1, d2) = site_dims(&site)?;
                    let roles = reg
                        .roles()
                        .iter()
                        .map(|r| Ok((r.to_string(), adapt_of(reg.tensor_name(&site, r))?)))
                        .collect::<Result<Vec<_>>>()?;
                    bindings.push(Binding {
                        base: base_idx[&site],
                        d1,
                        d2,
                        kind: BindKind::Generic { method: reg.clone(), roles },
                        site,
                    });
                }
            }
            "bitfit" => {
                let reg = method::get("bitfit")?;
                for site in zoo::bias_sites(parsed.model) {
                    let (d1, d2) = site_dims(&site)?;
                    let roles = vec![("delta".to_string(), adapt_of(reg.tensor_name(&site, "delta"))?)];
                    bindings.push(Binding {
                        base: base_idx[&site],
                        d1,
                        d2,
                        kind: BindKind::Generic { method: reg.clone(), roles },
                        site,
                    });
                }
            }
            "ff" => {
                let reg = method::get("dense")?;
                for bt in zoo::base_schema(parsed.model) {
                    let (d1, d2) = site_dims(&bt.name)?;
                    let roles =
                        vec![("delta".to_string(), adapt_of(reg.tensor_name(&bt.name, "delta"))?)];
                    bindings.push(Binding {
                        base: base_idx[&bt.name],
                        d1,
                        d2,
                        kind: BindKind::Generic { method: reg.clone(), roles },
                        site: bt.name,
                    });
                }
            }
            // lp trains only the head; adapter trains its bottlenecks
            // directly inside the trunk (no ΔW site).
            "lp" | "adapter" => {}
            other => bail!("host engine cannot train method '{other}'"),
        }

        // The shared entry matrix is sampled once on the fold-min grid
        // (engine::entry_grid_dims), but adapter-file reconstruction
        // resamples per-site from the seed. Those agree only when every
        // Fourier site shares one (d1, d2) — true for the whole zoo —
        // so refuse heterogeneous-dims fourierft up front rather than
        // train coefficients that would silently reconstruct differently
        // at serve time.
        let mut fourier_dims: Option<(usize, usize)> = None;
        for b in &bindings {
            if matches!(b.kind, BindKind::Fourier { .. }) {
                match fourier_dims {
                    None => fourier_dims = Some((b.d1, b.d2)),
                    Some(dims) if dims != (b.d1, b.d2) => bail!(
                        "fourierft sites with heterogeneous dims ({:?} vs {:?}): the \
                         shared entry matrix would diverge from per-site serving \
                         reconstruction",
                        dims,
                        (b.d1, b.d2)
                    ),
                    Some(_) => {}
                }
            }
        }

        let mut needs = model::Needs { head: parsed.method.head, ..Default::default() };
        for b in &bindings {
            if base_metas[b.base].shape.len() == 2 {
                needs.w.insert(b.base);
            } else {
                needs.b.insert(b.base);
            }
        }
        Ok(HostEngine { meta, net, bindings, needs, adapt_names, entries_static })
    }

    fn entries<'a>(&self, state: &'a ParamSet) -> Result<&'a Tensor> {
        let i = self
            .entries_static
            .ok_or_else(|| anyhow!("artifact {} has no 'entries' static", self.meta.name))?;
        state
            .statics
            .get(i)
            .ok_or_else(|| anyhow!("state is missing the 'entries' static (got {} statics)", state.statics.len()))
    }

    /// Materialize `W_eff = W₀ + ΔW` for every bound site.
    fn effective(&self, state: &ParamSet, scaling: f32) -> Result<HashMap<usize, Vec<f32>>> {
        let ctx = ReconstructCtx { seed: 0, alpha: scaling, meta: &[] };
        let mut eff = HashMap::new();
        for b in &self.bindings {
            let delta = match &b.kind {
                BindKind::Fourier { coef } => {
                    let e = self.entries(state)?.as_i32()?;
                    let n = e.len() / 2;
                    let p = plan::global().get((&e[..n], &e[n..]), b.d1, b.d2)?;
                    let c = state.adapt[*coef].as_f32()?;
                    Tensor::f32(&[b.d1, b.d2], p.reconstruct(c, scaling)?)
                }
                BindKind::Generic { method, roles } => {
                    let pairs: Vec<(&str, &Tensor)> =
                        roles.iter().map(|(r, i)| (r.as_str(), &state.adapt[*i])).collect();
                    let spec = SiteSpec { name: b.site.clone(), d1: b.d1, d2: b.d2 };
                    method.site_delta(&spec, &SiteTensors::from_pairs(&pairs), &ctx)?
                }
            };
            let base = &state.base[b.base];
            anyhow::ensure!(
                delta.shape == base.shape,
                "site {}: ΔW shape {:?} vs base shape {:?}",
                b.site,
                delta.shape,
                base.shape
            );
            let mut w = base.as_f32()?.to_vec();
            for (slot, &dv) in w.iter_mut().zip(delta.as_f32()?) {
                *slot += dv;
            }
            eff.insert(b.base, w);
        }
        Ok(eff)
    }

    /// Route ∂L/∂W_eff through each method's adjoint into per-adapt-tensor
    /// gradients, merged with the trunk's direct (head / adapter) grads.
    fn adapt_grads(
        &self,
        state: &ParamSet,
        mut grads: model::Grads,
        scaling: f32,
    ) -> Result<HashMap<usize, Vec<f32>>> {
        let ctx = ReconstructCtx { seed: 0, alpha: scaling, meta: &[] };
        let mut out = std::mem::take(&mut grads.adapt);
        for b in &self.bindings {
            let g = grads
                .base
                .remove(&b.base)
                .ok_or_else(|| anyhow!("backward produced no gradient for site {}", b.site))?;
            let g_t = Tensor::f32(&state.base[b.base].shape, g);
            match &b.kind {
                BindKind::Fourier { coef } => {
                    let e = self.entries(state)?.as_i32()?;
                    let n = e.len() / 2;
                    let p = plan::global().get((&e[..n], &e[n..]), b.d1, b.d2)?;
                    out.insert(*coef, p.coeff_grad(g_t.as_f32()?, scaling)?);
                }
                BindKind::Generic { method, roles } => {
                    let pairs: Vec<(&str, &Tensor)> =
                        roles.iter().map(|(r, i)| (r.as_str(), &state.adapt[*i])).collect();
                    let spec = SiteSpec { name: b.site.clone(), d1: b.d1, d2: b.d2 };
                    let role_grads =
                        method.site_delta_grad(&spec, &SiteTensors::from_pairs(&pairs), &ctx, &g_t)?;
                    for (role, gt) in role_grads {
                        let idx = roles
                            .iter()
                            .find(|(r, _)| *r == role)
                            .map(|(_, i)| *i)
                            .ok_or_else(|| {
                                anyhow!("site {}: adjoint returned unknown role '{role}'", b.site)
                            })?;
                        out.insert(idx, gt.as_f32()?.to_vec());
                    }
                }
            }
        }
        Ok(out)
    }

    /// Decoupled-weight-decay Adam over the adapt tensors that received a
    /// gradient; `head.*` tensors use the separate head learning rate.
    fn adam(
        &self,
        state: &mut ParamSet,
        grads: &HashMap<usize, Vec<f32>>,
        s: StepScalars,
    ) -> Result<()> {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        let bc1 = 1.0 - B1.powf(s.step);
        let bc2 = 1.0 - B2.powf(s.step);
        let ParamSet { adapt, m, v, .. } = state;
        for (i, name) in self.adapt_names.iter().enumerate() {
            let Some(g) = grads.get(&i) else { continue };
            let lr = if name.starts_with("head.") { s.lr_head } else { s.lr };
            let theta = adapt[i].as_f32_mut()?;
            anyhow::ensure!(
                g.len() == theta.len(),
                "gradient for '{name}' has {} elements, tensor has {}",
                g.len(),
                theta.len()
            );
            let mi = m[i].as_f32_mut()?;
            let vi = v[i].as_f32_mut()?;
            for j in 0..theta.len() {
                let gj = g[j];
                mi[j] = B1 * mi[j] + (1.0 - B1) * gj;
                vi[j] = B2 * vi[j] + (1.0 - B2) * gj * gj;
                let mh = mi[j] / bc1;
                let vh = vi[j] / bc2;
                theta[j] -= lr * (mh / (vh.sqrt() + EPS) + s.wd * theta[j]);
            }
        }
        Ok(())
    }

    fn validate_state_inputs(&self, base: &[Tensor], statics: &[Tensor]) -> Result<()> {
        let base_metas = self.meta.inputs_with_role("base");
        anyhow::ensure!(
            base.len() == base_metas.len(),
            "engine got {} base tensors, meta wants {}",
            base.len(),
            base_metas.len()
        );
        for (tm, t) in base_metas.iter().zip(base) {
            anyhow::ensure!(
                t.shape == tm.shape,
                "base tensor '{}' shape {:?}, meta wants {:?}",
                tm.name,
                t.shape,
                tm.shape
            );
        }
        let n_statics = self.meta.inputs_with_role("static").len();
        anyhow::ensure!(
            statics.len() == n_statics,
            "engine got {} statics, meta wants {n_statics}",
            statics.len()
        );
        Ok(())
    }

    /// Gradients of the current state on one batch, keyed by adapt tensor
    /// name — exposed for finite-difference validation in tests and not
    /// part of the engine trait.
    pub fn grads_by_name(
        &self,
        state: &ParamSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<HashMap<String, Vec<f32>>> {
        let eff = self.effective(state, scaling)?;
        let w = model::Weights { base: &state.base, eff: &eff };
        let fwd = self.net.forward(&w, &state.adapt, batch, true)?;
        let tape = fwd.tape.expect("tape requested");
        let grads = self.net.backward(&w, &state.adapt, &tape, &self.needs)?;
        let by_idx = self.adapt_grads(state, grads, scaling)?;
        Ok(by_idx
            .into_iter()
            .map(|(i, g)| (self.adapt_names[i].clone(), g))
            .collect())
    }
}

impl StepEngine for HostEngine {
    fn id(&self) -> &'static str {
        "host"
    }

    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn init_state(
        &self,
        seed: i32,
        base: Vec<Tensor>,
        statics: Vec<Tensor>,
    ) -> Result<ParamSet> {
        self.validate_state_inputs(&base, &statics)?;
        let entries = self.entries_static.map(|i| &statics[i]);
        let mut adapt = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for tm in self.meta.inputs_with_role("adapt") {
            adapt.push(model::init_adapt_tensor(&self.meta.name, tm, seed as i64, entries)?);
            m.push(Tensor::zeros(&tm.shape));
            v.push(Tensor::zeros(&tm.shape));
        }
        Ok(ParamSet { base, adapt, m, v, statics })
    }

    fn step(
        &self,
        state: &mut ParamSet,
        scalars: StepScalars,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        let eff = self.effective(state, scalars.scaling)?;
        let (loss, logits, by_idx) = {
            let w = model::Weights { base: &state.base, eff: &eff };
            let fwd = self.net.forward(&w, &state.adapt, batch, true)?;
            let tape = fwd.tape.expect("tape requested");
            let grads = self.net.backward(&w, &state.adapt, &tape, &self.needs)?;
            (fwd.loss, fwd.logits, self.adapt_grads(state, grads, scalars.scaling)?)
        };
        self.adam(state, &by_idx, scalars)?;
        Ok(StepOut { loss, logits })
    }

    fn eval(
        &self,
        state: &mut ParamSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        let eff = self.effective(state, scaling)?;
        let w = model::Weights { base: &state.base, eff: &eff };
        let fwd = self.net.forward(&w, &state.adapt, batch, false)?;
        Ok(StepOut { loss: fwd.loss, logits: fwd.logits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mlp_batch(seed: u64) -> HashMap<String, Tensor> {
        crate::data::blobs::collate(&crate::data::blobs::dataset(64, 0.35, seed))
    }

    #[test]
    fn mlp_engine_builds_inits_and_steps() {
        let eng = HostEngine::from_artifact("mlp__fourierft_n32__ce").unwrap();
        let base = zoo::init_base_for(eng.meta(), 0).unwrap();
        let (statics, _) = crate::runtime::engine::make_statics(
            eng.meta(),
            2024,
            crate::fourier::EntryBias::None,
        )
        .unwrap();
        let mut state = eng.init_state(0, base, statics).unwrap();
        let scalars =
            StepScalars { step: 1.0, lr: 5e-2, lr_head: 2e-3, wd: 0.0, scaling: 64.0 };
        let out = eng.step(&mut state, scalars, &mlp_batch(1)).unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.logits.shape, vec![64, 8]);
        // coefficients moved off the zero init
        let coef_idx =
            eng.adapt_names.iter().position(|n| n == "spec.hid.w.c").unwrap();
        assert!(state.adapt[coef_idx].frob_norm() > 0.0);
    }

    #[test]
    fn eval_is_side_effect_free() {
        let eng = HostEngine::from_artifact("mlp__lora_r2__ce").unwrap();
        let base = zoo::init_base_for(eng.meta(), 0).unwrap();
        let mut state = eng.init_state(0, base, vec![]).unwrap();
        let snapshot = state.clone();
        let batch = mlp_batch(2);
        let a = eng.eval(&mut state, 2.0, &batch).unwrap();
        let b = eng.eval(&mut state, 2.0, &batch).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (x, y) in snapshot.adapt.iter().zip(&state.adapt) {
            assert_eq!(x, y, "eval must not mutate adapt tensors");
        }
    }

    #[test]
    fn set_adapt_roundtrips_through_trait() {
        let eng = HostEngine::from_artifact("mlp__circulant__ce").unwrap();
        let base = zoo::init_base_for(eng.meta(), 0).unwrap();
        let mut state = eng.init_state(3, base, vec![]).unwrap();
        let tensors: HashMap<String, Tensor> =
            eng.adapt_tensors(&state).unwrap().into_iter().collect();
        assert!(tensors.contains_key("circ.hid.w.c"));
        eng.set_adapt(&mut state, &tensors).unwrap();
        // missing tensor is an error
        let empty = HashMap::new();
        assert!(eng.set_adapt(&mut state, &empty).is_err());
    }

    #[test]
    fn frozen_head_stays_frozen() {
        let eng = HostEngine::from_artifact("mlp__fourierft_n16_fh__ce").unwrap();
        let base = zoo::init_base_for(eng.meta(), 0).unwrap();
        let (statics, _) = crate::runtime::engine::make_statics(
            eng.meta(),
            7,
            crate::fourier::EntryBias::None,
        )
        .unwrap();
        let head_before = base[eng
            .meta()
            .inputs_with_role("base")
            .iter()
            .position(|t| t.name == "head.w")
            .unwrap()]
        .clone();
        let mut state = eng.init_state(0, base, statics).unwrap();
        let scalars =
            StepScalars { step: 1.0, lr: 5e-2, lr_head: 2e-3, wd: 0.0, scaling: 64.0 };
        for s in 1..4 {
            let mut sc = scalars;
            sc.step = s as f32;
            eng.step(&mut state, sc, &mlp_batch(s as u64)).unwrap();
        }
        let head_pos = eng
            .meta()
            .inputs_with_role("base")
            .iter()
            .position(|t| t.name == "head.w")
            .unwrap();
        assert_eq!(state.base[head_pos], head_before, "frozen head must not train");
    }
}
