//! Pure-Rust stand-in for the `xla` crate, used when the `xla-runtime`
//! feature is disabled (the default, offline build).
//!
//! Everything in the crate refers to the runtime through the
//! `crate::runtime::xla` alias, which resolves either to the real `xla`
//! crate (feature `xla-runtime`) or to this module. Host-side literal
//! plumbing (`Literal`, shapes, dtype round-trips) is fully functional so
//! the adapter/serving/reconstruction stack — and its tests — run without
//! XLA; only compiling/executing HLO artifacts returns an error pointing
//! at the feature flag.

use crate::tensor::{Data, Tensor};
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

/// Element dtypes the artifact ABI uses. Mirrors `xla::ElementType` for the
/// variants the coordinator touches; the extra variants keep wildcard match
/// arms at call sites reachable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    S64,
    Pred,
}

/// Shape of a dense array literal: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Scalar types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Result<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }

    fn unwrap(d: &Data) -> Result<&[f32]> {
        match d {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("literal holds i32, expected f32"),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }

    fn unwrap(d: &Data) -> Result<&[i32]> {
        match d {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("literal holds f32, expected i32"),
        }
    }
}

/// Host literal: a dense tensor with shape metadata, or a tuple of
/// literals. The real `xla::Literal` has no `Clone`; this one keeps the
/// same API surface the coordinator uses (construction via `vec1` +
/// `reshape` / [`Literal::tuple`], extraction via `to_vec` /
/// `to_tuple`). It *is* `Clone` (a host-vector copy), which
/// `exec::clone_literal` uses as a fast path when deep-copying per-worker
/// serve state — callers must still go through `clone_literal` so the
/// real-runtime build keeps compiling.
///
/// Tuple support mirrors the real literal's semantics (HLO computations
/// return their outputs as one tuple), so engine-neutral code can
/// decompose results without feature-forked error handling.
#[derive(Debug, Clone)]
pub struct Literal {
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    Array(Tensor),
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice (or anything slice-like).
    pub fn vec1<T: NativeType>(v: impl AsRef<[T]>) -> Literal {
        let v = v.as_ref();
        Literal {
            repr: Repr::Array(Tensor { shape: vec![v.len()], data: T::wrap(v.to_vec()) }),
        }
    }

    /// Tuple literal from element literals (what executing a fused step
    /// artifact returns on the real runtime).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { repr: Repr::Tuple(elements) }
    }

    fn array(&self, what: &str) -> Result<&Tensor> {
        match &self.repr {
            Repr::Array(t) => Ok(t),
            Repr::Tuple(v) => bail!("{what} on a tuple literal of {} elements", v.len()),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let tensor = self.array("reshape")?;
        let shape: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let numel: usize = shape.iter().product();
        if numel != tensor.len() {
            bail!("reshape {:?} on literal of {} elements", dims, tensor.len());
        }
        Ok(Literal { repr: Repr::Array(Tensor { shape, data: tensor.data.clone() }) })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        let tensor = self.array("array_shape")?;
        let ty = match tensor.data {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: tensor.shape.iter().map(|&d| d as i64).collect(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(T::unwrap(&self.array("to_vec")?.data)?.to_vec())
    }

    /// Decompose a tuple literal into its elements. Mirrors the real
    /// runtime: calling it on an array literal is an error, not a
    /// single-element tuple.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(v) => Ok(v),
            Repr::Array(t) => bail!("to_tuple on an array literal of shape {:?}", t.shape),
        }
    }

    /// Decompose a 1-tuple into its single element.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut v = self.to_tuple()?;
        if v.len() != 1 {
            bail!("to_tuple1 on a tuple of {} elements", v.len());
        }
        Ok(v.pop().unwrap())
    }
}

/// Parsed HLO module. The fallback cannot parse HLO text; constructing one
/// is the first step of every compile path and fails with a clear pointer.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        bail!(
            "cannot load HLO artifact {:?}: built without the `xla-runtime` feature \
             (rebuild with `--features xla-runtime` and a vendored `xla` crate)",
            path.as_ref()
        )
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device client stand-in. Creating one succeeds (it is just a handle) so
/// pure-host consumers can hold a `Client`; compiling fails.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "host-only (xla-runtime disabled)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!("compiling HLO requires the `xla-runtime` feature")
    }
}

/// Compiled executable stand-in; never constructible in the fallback, so
/// `execute` is unreachable but must typecheck for both `Literal` and
/// `&Literal` argument forms.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!("executing HLO requires the `xla-runtime` feature")
    }
}

/// Device buffer stand-in.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: Arc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!("device buffers require the `xla-runtime` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_to_vec() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_len_mismatch_errors() {
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_literals_compose_and_decompose() {
        let a = Literal::vec1(&[1.0f32, 2.0]);
        let b = Literal::vec1(&[7i32]);
        let tup = Literal::tuple(vec![a, b]);
        // array ops on a tuple are errors, mirroring the real runtime
        assert!(tup.array_shape().is_err());
        assert!(tup.to_vec::<f32>().is_err());
        let parts = tup.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn to_tuple1_unwraps_singletons_only() {
        let one = Literal::tuple(vec![Literal::vec1(&[3.0f32])]);
        assert_eq!(one.to_tuple1().unwrap().to_vec::<f32>().unwrap(), vec![3.0]);
        let two = Literal::tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2i32])]);
        assert!(two.to_tuple1().is_err());
        // array literals are not 1-tuples
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn compile_paths_point_at_feature() {
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(format!("{err}").contains("xla-runtime"));
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("disabled"));
    }
}
