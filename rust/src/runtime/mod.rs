//! Runtime backends behind the [`StepEngine`] trait ([`engine`]):
//! the pure-host training engine ([`host`]) and the PJRT/XLA path below.
//!
//! The XLA side is the only code that touches the `xla` crate. The flow
//! (see /opt/xla-example/load_hlo) is:
//!
//!   HLO text --HloModuleProto::from_text_file--> XlaComputation
//!            --PjRtClient::cpu().compile--> PjRtLoadedExecutable
//!            --execute / execute_b--> PjRtBuffers
//!
//! HLO *text* (not serialized proto) is the interchange format: jax >= 0.5
//! emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! Python is never on this path — artifacts are built once by
//! `make artifacts` and the binary is self-contained afterwards.

pub mod artifact;
pub mod engine;
pub mod exec;
pub mod host;
#[cfg(not(feature = "xla-runtime"))]
pub mod xla_compat;

/// The runtime backend. With the `xla-runtime` feature this is the real
/// `xla` crate (PJRT over vendored XLA); without it, the pure-Rust
/// stand-in in [`xla_compat`] (host literals work, compiling/executing HLO
/// errors). All code in this crate goes through this alias.
#[cfg(feature = "xla-runtime")]
pub use ::xla;
#[cfg(not(feature = "xla-runtime"))]
pub use xla_compat as xla;

pub use artifact::{ArtifactMeta, Registry, TensorMeta};
pub use engine::{EngineKind, ParamSet, StepEngine, StepOut, StepScalars};
pub use exec::{Executable, XlaEngine};
pub use host::HostEngine;

use crate::tensor::{Data, Tensor};
use anyhow::Result;
use std::sync::Arc;

/// Shared PJRT client. Creating a CPU client is cheap but not free; the
/// coordinator makes exactly one and threads it everywhere.
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        Ok(Client { inner: Arc::new(xla::PjRtClient::cpu()?) })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Compile an HLO text file into an executable.
    pub fn load_hlo(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.inner.compile(&comp)?)
    }
}

/// Host tensor -> XLA literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        Data::F32(v) => xla::Literal::vec1(v),
        Data::I32(v) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

/// XLA literal -> host tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let t = match shape.ty() {
        xla::ElementType::F32 => Tensor::f32(&dims, lit.to_vec::<f32>()?),
        xla::ElementType::S32 => Tensor::i32(&dims, lit.to_vec::<i32>()?),
        other => anyhow::bail!("unsupported element type {:?}", other),
    };
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = Tensor::scalar_i32(42);
        let back = from_literal(&to_literal(&t).unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
