//! Backend-neutral step engine: the one trait every training / serving
//! consumer dispatches through.
//!
//! Historically the whole step path (`Trainer`, `pretrain`, the experiment
//! drivers, the `Server`) was hard-wired to XLA `Executable`s and
//! `xla::Literal` state, which meant the default offline build could
//! reconstruct and serve adapters but never *train* them. This module
//! splits that coupling:
//!
//! * [`StepEngine`] — `init_state / step / eval / adapt_tensors /
//!   set_adapt` over a backend-neutral [`ParamSet`] holding host
//!   [`Tensor`]s. Two implementations exist:
//!   [`XlaEngine`](super::exec::XlaEngine), a thin wrapper over the
//!   compiled-HLO [`Executable`](super::exec::Executable) (usable only
//!   with the `xla-runtime` feature + `artifacts/`), and
//!   [`HostEngine`](super::host::HostEngine), a pure-Rust forward +
//!   analytic-backward engine over the sim model zoo that trains in the
//!   default build.
//! * [`make_statics`] — the frozen method inputs (spectral entry matrix,
//!   ablation bases) as host tensors, engine-independent. The entry grid
//!   is derived from each adapted site's actual (d1, d2) recorded in the
//!   artifact meta (fold-min across sites), fixing the old square-dims
//!   assumption `sample_entries(d, d, …)`.
//!
//! Selection is a CLI flag (`repro … --engine {host,xla}`); `host` is the
//! default so every default-build binary trains end-to-end.

use super::artifact::ArtifactMeta;
use crate::fourier::{sample_entries, EntryBias};
use crate::tensor::{linalg, rng::Rng, Tensor};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Scalar hyperparameters fed to every step call.
#[derive(Debug, Clone, Copy)]
pub struct StepScalars {
    /// 1-based Adam step count.
    pub step: f32,
    pub lr: f32,
    /// Task-head learning rate (the paper tunes it separately; dense head
    /// weights want a much smaller rate than spectral coefficients).
    pub lr_head: f32,
    pub wd: f32,
    /// FourierFT alpha, or LoRA alpha/r, per method semantics.
    pub scaling: f32,
}

/// Result of one step call.
pub struct StepOut {
    pub loss: f32,
    pub logits: Tensor,
}

/// Mutable training state at the engine boundary: host tensors aligned
/// with the artifact meta's per-role input order. Backends that need
/// device representations (XLA literals) convert at the trait edge.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub base: Vec<Tensor>,
    pub adapt: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub statics: Vec<Tensor>,
}

impl ParamSet {
    /// Deep copy, for per-worker serve state. Host tensors clone directly;
    /// the `Result` return is kept so call sites stay uniform with the
    /// old literal-backed state (whose real-runtime clone could fail).
    pub fn try_clone(&self) -> Result<ParamSet> {
        Ok(self.clone())
    }
}

/// Conditional `Send + Sync` bound for engine trait objects: the compat
/// backend (and the host engine) are thread-safe, so the concurrent
/// scheduler can share one engine across workers; the vendored real
/// `xla` crate's PJRT handles are not, so the `xla-runtime` build drops
/// the bound (and serves sequentially — see `Server::serve_scheduled`).
#[cfg(not(feature = "xla-runtime"))]
pub trait EngineBound: Send + Sync {}
#[cfg(not(feature = "xla-runtime"))]
impl<T: Send + Sync> EngineBound for T {}
#[cfg(feature = "xla-runtime")]
pub trait EngineBound {}
#[cfg(feature = "xla-runtime")]
impl<T> EngineBound for T {}

/// A training/eval backend for one artifact family.
///
/// The contract mirrors the fused HLO step artifact: `step` rolls the
/// Adam state forward and returns (loss, logits); `eval` is a
/// side-effect-free forward pass; `adapt_tensors` / `set_adapt` move the
/// trainable tensors across the boundary by name (adapter publish /
/// hot-swap). All tensors at this boundary are host [`Tensor`]s.
pub trait StepEngine: EngineBound {
    /// Engine identifier (`"host"` / `"xla"`), recorded in cached `.base`
    /// files so bases from different engines are never silently mixed.
    fn id(&self) -> &'static str;

    /// The artifact meta this engine was built for (tensor-level ABI).
    fn meta(&self) -> &ArtifactMeta;

    /// Seeded init of the trainable state: fresh (adapt, m, v) around the
    /// given base and statics.
    fn init_state(&self, seed: i32, base: Vec<Tensor>, statics: Vec<Tensor>)
        -> Result<ParamSet>;

    /// One fused train step. Mutates `state` (adapt/m/v roll forward).
    fn step(
        &self,
        state: &mut ParamSet,
        scalars: StepScalars,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut>;

    /// Pure evaluation on a batch; `state` is unchanged on return.
    fn eval(
        &self,
        state: &mut ParamSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut>;

    /// Extract the current adapt tensors as (name, tensor) pairs.
    fn adapt_tensors(&self, state: &ParamSet) -> Result<Vec<(String, Tensor)>> {
        let metas = self.meta().inputs_with_role("adapt");
        anyhow::ensure!(
            metas.len() == state.adapt.len(),
            "state has {} adapt tensors, meta wants {}",
            state.adapt.len(),
            metas.len()
        );
        Ok(metas
            .iter()
            .zip(&state.adapt)
            .map(|(m, t)| (m.name.clone(), t.clone()))
            .collect())
    }

    /// Replace adapt tensors from host tensors (adapter hot-load path).
    fn set_adapt(&self, state: &mut ParamSet, tensors: &HashMap<String, Tensor>) -> Result<()> {
        let metas = self.meta().inputs_with_role("adapt");
        let mut new_adapt = Vec::with_capacity(metas.len());
        for m in metas {
            let t = tensors
                .get(&m.name)
                .ok_or_else(|| anyhow!("missing adapt tensor '{}'", m.name))?;
            anyhow::ensure!(
                t.shape == m.shape,
                "adapt tensor '{}' shape {:?}, engine wants {:?}",
                m.name,
                t.shape,
                m.shape
            );
            new_adapt.push(t.clone());
        }
        state.adapt = new_adapt;
        Ok(())
    }
}

/// Which [`StepEngine`] implementation a `Trainer` builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust forward + analytic backward ([`super::host`]); trains in
    /// the default build with no artifacts.
    Host,
    /// Compiled HLO artifacts via PJRT (needs `artifacts/` and, to
    /// actually execute, the `xla-runtime` feature).
    Xla,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        match s {
            "host" => Ok(EngineKind::Host),
            "xla" => Ok(EngineKind::Xla),
            other => Err(anyhow!("unknown engine '{other}' (expected 'host' or 'xla')")),
        }
    }

    pub fn id(self) -> &'static str {
        match self {
            EngineKind::Host => "host",
            EngineKind::Xla => "xla",
        }
    }
}

/// Spectral grid (d1, d2) for the shared entry matrix of `meta`.
///
/// Every adapted site's actual dims are read from the artifact meta (the
/// method's legacy-name classifier maps adapt-tensor names to site names,
/// whose base weights carry shapes); the fold-min across sites keeps the
/// sampled frequencies valid at every site when dims differ. Falls back
/// to the model-kind heuristic (`hidden` for mlp/denoiser, else `d`) for
/// metas that expose no classifiable sites.
pub fn entry_grid_dims(meta: &ArtifactMeta) -> (usize, usize) {
    let fb = if meta.model.kind == "mlp" || meta.model.kind == "denoiser" {
        meta.model.hidden
    } else {
        meta.model.d
    };
    let method = match crate::adapter::method::get(&meta.method.name) {
        Ok(m) => m,
        Err(_) => return (fb, fb),
    };
    let site_dims = meta.site_dims();
    let mut dims: Option<(usize, usize)> = None;
    for t in meta.inputs_with_role("adapt") {
        if let Some((site, _)) = method.classify_legacy(&t.name) {
            if let Some(&(a, b)) = site_dims.get(&site) {
                dims = Some(match dims {
                    None => (a, b),
                    Some((x, y)) => (x.min(a), y.min(b)),
                });
            }
        }
    }
    dims.unwrap_or((fb, fb))
}

/// Frozen method inputs (role = "static") for an artifact, as host
/// tensors (engine-independent; backends convert if they need device
/// literals):
///
/// * `fourierft` / `loca`: the shared entry matrix E (seeded, optional
///   Eq. 5 bias) over the per-site grid from [`entry_grid_dims`]
/// * `randbasis`: Gaussian basis pair B1, B2
/// * `orthobasis`: Haar-orthogonal basis pair (QR of Gaussian)
///
/// Returns the static tensors in meta order plus the sampled entry
/// (rows, cols) when an entry matrix was produced.
///
/// Caveat (pre-existing, engine-independent): adapter files store only
/// the entry *seed*, and reconstruction resamples with
/// [`EntryBias::None`] — so adapters trained with a biased entry matrix
/// (the Figure 5 ablation) reconstruct correctly only inside the run
/// that trained them, not from a published file.
pub fn make_statics(
    meta: &ArtifactMeta,
    entry_seed: u64,
    bias: EntryBias,
) -> Result<(Vec<Tensor>, Option<(Vec<i32>, Vec<i32>)>)> {
    let statics = meta.inputs_with_role("static");
    if statics.is_empty() {
        return Ok((vec![], None));
    }
    let n = meta.method.n;
    let (d1, d2) = entry_grid_dims(meta);
    let (rows, cols) = sample_entries(d1, d2, n, bias, entry_seed)?;
    let mut e_data = rows.clone();
    e_data.extend(&cols);
    let entries_t = Tensor::i32(&[2, n], e_data);

    let mut out = Vec::new();
    let mut used_entries = false;
    for t in &statics {
        match t.name.as_str() {
            "entries" => {
                used_entries = true;
                out.push(entries_t.clone());
            }
            "basis1" | "basis2" => {
                let dim = t.shape[0];
                let tag = if t.name == "basis1" { 1 } else { 2 };
                let mut rng = Rng::new(entry_seed ^ (0xBA5E << 8) ^ tag);
                let g = Tensor::f32(&[dim, dim], rng.normal_vec(dim * dim, 1.0));
                let b = if meta.method.name == "orthobasis" { linalg::qr_q(&g)? } else { g };
                out.push(b);
            }
            other => anyhow::bail!("unknown static input {other}"),
        }
    }
    Ok((out, used_entries.then_some((rows, cols))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{MethodMeta, ModelMeta, TensorMeta};

    fn meta_with_sites(sites: &[(&str, usize, usize)], n: usize) -> ArtifactMeta {
        let mut inputs = Vec::new();
        for (name, d1, d2) in sites {
            inputs.push(TensorMeta {
                name: name.to_string(),
                role: "base".into(),
                dtype: "f32".into(),
                shape: vec![*d1, *d2],
            });
            inputs.push(TensorMeta {
                name: format!("spec.{name}.c"),
                role: "adapt".into(),
                dtype: "f32".into(),
                shape: vec![n],
            });
        }
        inputs.push(TensorMeta {
            name: "entries".into(),
            role: "static".into(),
            dtype: "i32".into(),
            shape: vec![2, n],
        });
        ArtifactMeta {
            name: "test__fourierft__ce".into(),
            loss: "ce".into(),
            model: ModelMeta { kind: "encoder".into(), d: 999, ..Default::default() },
            method: MethodMeta { name: "fourierft".into(), n, ..Default::default() },
            inputs,
            outputs: vec![],
            step_hlo: String::new(),
            init_hlo: String::new(),
            trainable: 0,
            trainable_ex_head: 0,
        }
    }

    #[test]
    fn entry_grid_uses_per_site_dims_not_model_d() {
        // One 24x16 and one 16x24 site: the shared grid must be the
        // fold-min (16, 16), never the bogus model d = 999.
        let meta = meta_with_sites(&[("a.w", 24, 16), ("b.w", 16, 24)], 8);
        assert_eq!(entry_grid_dims(&meta), (16, 16));
    }

    #[test]
    fn statics_entries_are_valid_for_non_square_sites() {
        let meta = meta_with_sites(&[("a.w", 24, 16)], 12);
        let (statics, entries) = make_statics(&meta, 2024, EntryBias::None).unwrap();
        assert_eq!(statics.len(), 1);
        assert_eq!(statics[0].shape, vec![2, 12]);
        let (rows, cols) = entries.unwrap();
        assert!(rows.iter().all(|&r| (0..24).contains(&r)));
        assert!(cols.iter().all(|&c| (0..16).contains(&c)));
    }

    #[test]
    fn engine_kind_parses_and_rejects() {
        assert_eq!(EngineKind::parse("host").unwrap(), EngineKind::Host);
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert_eq!(EngineKind::Host.id(), "host");
        assert!(EngineKind::parse("tpu").is_err());
    }

    #[test]
    fn no_statics_is_empty() {
        let mut meta = meta_with_sites(&[("a.w", 8, 8)], 4);
        meta.inputs.retain(|t| t.role != "static");
        let (statics, entries) = make_statics(&meta, 1, EntryBias::None).unwrap();
        assert!(statics.is_empty());
        assert!(entries.is_none());
    }
}
