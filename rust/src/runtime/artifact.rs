//! Artifact registry: schema-driven loading of `artifacts/*.hlo.txt` plus
//! their `.meta.json` sidecars emitted by `python/compile/aot.py`.
//!
//! The meta JSON is the tensor-level ABI between L2 (jax) and L3 (rust):
//! an ordered list of inputs/outputs with name, dtype, shape, and *role*
//! (base / adapt / opt_m / opt_v / static / scalar / batch / loss / logits).
//! Nothing about parameter layout is hard-coded on the rust side.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor slot in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub role: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorMeta> {
        Ok(TensorMeta {
            name: v.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("no name"))?.into(),
            role: v.get("role").and_then(Json::as_str).unwrap_or("").into(),
            dtype: v.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("no dtype"))?.into(),
            shape: v
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("no shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// Method hyperparameters recorded at lowering time.
#[derive(Debug, Clone, Default)]
pub struct MethodMeta {
    pub name: String,
    pub r: usize,
    pub n: usize,
    pub m: usize,
}

/// Model hyperparameters recorded at lowering time.
#[derive(Debug, Clone, Default)]
pub struct ModelMeta {
    pub name: String,
    pub kind: String,
    pub d: usize,
    pub layers: usize,
    pub vocab: usize,
    pub seqlen: usize,
    pub classes: usize,
    pub batch: usize,
    pub img: usize,
    pub patch: usize,
    pub channels: usize,
    pub hidden: usize,
}

/// Parsed `.meta.json` for one artifact family (step + init).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub loss: String,
    pub model: ModelMeta,
    pub method: MethodMeta,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub step_hlo: String,
    pub init_hlo: String,
    pub trainable: usize,
    pub trainable_ex_head: usize,
}

impl ArtifactMeta {
    pub fn parse(doc: &Json) -> Result<ArtifactMeta> {
        let get_str = |k: &str| -> Result<String> {
            Ok(doc.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("missing {k}"))?.into())
        };
        let model = doc.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let method = doc.get("method").ok_or_else(|| anyhow!("missing method"))?;
        let usize_of = |v: &Json, k: &str| v.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(ArtifactMeta {
            name: get_str("name")?,
            loss: get_str("loss")?,
            model: ModelMeta {
                name: model.get("name").and_then(Json::as_str).unwrap_or("").into(),
                kind: model.get("kind").and_then(Json::as_str).unwrap_or("").into(),
                d: usize_of(model, "d"),
                layers: usize_of(model, "layers"),
                vocab: usize_of(model, "vocab"),
                seqlen: usize_of(model, "seqlen"),
                classes: usize_of(model, "classes"),
                batch: usize_of(model, "batch"),
                img: usize_of(model, "img"),
                patch: usize_of(model, "patch"),
                channels: usize_of(model, "channels"),
                hidden: usize_of(model, "hidden"),
            },
            method: MethodMeta {
                name: method.get("name").and_then(Json::as_str).unwrap_or("").into(),
                r: usize_of(method, "r"),
                n: usize_of(method, "n"),
                m: usize_of(method, "m"),
            },
            inputs: doc
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing inputs"))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<_>>()?,
            outputs: doc
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing outputs"))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<_>>()?,
            step_hlo: get_str("step_hlo")?,
            init_hlo: get_str("init_hlo")?,
            trainable: doc.path(&["counts", "trainable"]).and_then(Json::as_usize).unwrap_or(0),
            trainable_ex_head: doc
                .path(&["counts", "trainable_ex_head"])
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }

    pub fn inputs_with_role(&self, role: &str) -> Vec<&TensorMeta> {
        self.inputs.iter().filter(|t| t.role == role).collect()
    }

    pub fn outputs_with_role(&self, role: &str) -> Vec<&TensorMeta> {
        self.outputs.iter().filter(|t| t.role == role).collect()
    }

    /// (d1, d2) of every adaptable 2-D base weight, keyed by tensor name —
    /// the site-dims map the serving caches use as a v1 fallback and the
    /// publish path stamps into v2 adapter files.
    pub fn site_dims(&self) -> BTreeMap<String, (usize, usize)> {
        self.inputs_with_role("base")
            .iter()
            .filter(|t| t.shape.len() == 2)
            .map(|t| (t.name.clone(), (t.shape[0], t.shape[1])))
            .collect()
    }

    /// Shape of the logits output.
    pub fn logits_shape(&self) -> Result<&[usize]> {
        self.outputs
            .iter()
            .find(|t| t.role == "logits")
            .map(|t| t.shape.as_slice())
            .ok_or_else(|| anyhow!("artifact {} has no logits output", self.name))
    }
}

/// Registry over the `artifacts/` directory: global manifest + per-family
/// meta, with lazy access by artifact name.
pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Json,
    metas: BTreeMap<String, ArtifactMeta>,
}

impl Registry {
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let mut metas = BTreeMap::new();
        for spec in manifest.get("specs").and_then(Json::as_arr).unwrap_or(&[]) {
            let meta = ArtifactMeta::parse(spec)?;
            metas.insert(meta.name.clone(), meta);
        }
        Ok(Registry { dir: dir.to_path_buf(), manifest, metas })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.metas.keys().map(String::as_str)
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} available; e.g. {:?})",
                self.metas.len(),
                self.metas.keys().take(3).collect::<Vec<_>>()
            )
        })
    }

    /// Find the artifact for (model, method-tag, loss), e.g.
    /// ("enc_base", "fourierft_n64", "ce").
    pub fn find(&self, model: &str, method_tag: &str, loss: &str) -> Result<&ArtifactMeta> {
        let name = format!("{model}__{method_tag}__{loss}");
        self.meta(&name)
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Base-model init HLO path + tensor list for an architecture.
    pub fn base_init(&self, model: &str) -> Result<(PathBuf, Vec<TensorMeta>)> {
        let b = self
            .manifest
            .path(&["bases", model])
            .ok_or_else(|| anyhow!("no base entry for model {model}"))?;
        let hlo = b.get("base_hlo").and_then(Json::as_str).ok_or_else(|| anyhow!("no base_hlo"))?;
        let tensors = b
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("no base tensors"))?
            .iter()
            .map(|t| {
                Ok(TensorMeta {
                    name: t.get("name").and_then(Json::as_str).unwrap_or("").into(),
                    role: "base".into(),
                    dtype: t.get("dtype").and_then(Json::as_str).unwrap_or("f32").into(),
                    shape: t
                        .get("shape")
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok((self.dir.join(hlo), tensors))
    }

    /// Standalone ΔW-reconstruction artifact for (d, n), if lowered.
    pub fn delta_hlo(&self, d: usize, n: usize) -> Result<PathBuf> {
        for e in self.manifest.get("deltas").and_then(Json::as_arr).unwrap_or(&[]) {
            if e.get("d").and_then(Json::as_usize) == Some(d)
                && e.get("n").and_then(Json::as_usize) == Some(n)
            {
                let hlo = e.get("hlo").and_then(Json::as_str).unwrap();
                return Ok(self.dir.join(hlo));
            }
        }
        bail!("no delta artifact for d={d}, n={n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> Json {
        Json::parse(
            r#"{
          "name": "m__fourierft_n8__ce", "loss": "ce",
          "model": {"name": "m", "kind": "encoder", "d": 16, "layers": 1,
                    "vocab": 10, "seqlen": 4, "classes": 3, "batch": 2,
                    "img": 0, "patch": 0, "channels": 0, "hidden": 0,
                    "heads": 2, "dff": 32},
          "method": {"name": "fourierft", "r": 0, "n": 8, "m": 0},
          "inputs": [
            {"name": "tok_emb", "role": "base", "dtype": "f32", "shape": [10, 16]},
            {"name": "spec.w.c", "role": "adapt", "dtype": "f32", "shape": [8]},
            {"name": "entries", "role": "static", "dtype": "i32", "shape": [2, 8]},
            {"name": "x", "role": "batch", "dtype": "i32", "shape": [2, 4]}
          ],
          "outputs": [
            {"name": "spec.w.c", "role": "adapt", "dtype": "f32", "shape": [8]},
            {"name": "loss", "role": "loss", "dtype": "f32", "shape": []},
            {"name": "logits", "role": "logits", "dtype": "f32", "shape": [2, 3]}
          ],
          "step_hlo": "a.step.hlo.txt", "init_hlo": "a.init.hlo.txt",
          "counts": {"trainable": 100, "trainable_ex_head": 64, "head": 36}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_meta() {
        let m = ArtifactMeta::parse(&sample_meta()).unwrap();
        assert_eq!(m.method.n, 8);
        assert_eq!(m.inputs_with_role("base").len(), 1);
        assert_eq!(m.logits_shape().unwrap(), &[2, 3]);
        assert_eq!(m.trainable_ex_head, 64);
        assert_eq!(m.inputs[2].numel(), 16);
    }

    #[test]
    fn missing_fields_error() {
        let bad = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(ArtifactMeta::parse(&bad).is_err());
    }
}
