//! Executable wrapper: schema-driven argument assembly + train state.
//!
//! One [`Executable`] owns a compiled step module and its [`ArtifactMeta`].
//! The fused step artifact computes `(adapt', m', v', loss, logits)` from
//! `(base, adapt, m, v, statics, scalars, batch)`; running it with `lr = 0`
//! is a pure eval (the L2 lowering guarantees this — see train.py).
//!
//! State tensors are kept as `xla::Literal`s between steps: the output
//! tuple is decomposed and its adapt/m/v slots become next step's inputs
//! verbatim, so there is no host re-encode in the loop.

use super::artifact::ArtifactMeta;
use super::{from_literal, to_literal, xla, Client};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Scalar hyperparameters fed to every step call.
#[derive(Debug, Clone, Copy)]
pub struct StepScalars {
    /// 1-based Adam step count.
    pub step: f32,
    pub lr: f32,
    /// Task-head learning rate (the paper tunes it separately; dense head
    /// weights want a much smaller rate than spectral coefficients).
    pub lr_head: f32,
    pub wd: f32,
    /// FourierFT alpha, or LoRA alpha/r, per method semantics.
    pub scaling: f32,
}

/// Mutable training state: literals aligned with the meta's per-role order.
pub struct ParamSet {
    pub base: Vec<xla::Literal>,
    pub adapt: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub statics: Vec<xla::Literal>,
}

impl ParamSet {
    /// Deep copy, for per-worker serve state: the concurrent scheduler
    /// gives every worker its own `ParamSet` so adapter hot-swaps and the
    /// eval-time m/v roll never race across threads. Real-runtime
    /// literals round-trip through host bytes ([`clone_literal`]); the
    /// compat backend clones host tensors directly.
    pub fn try_clone(&self) -> Result<ParamSet> {
        fn dup(v: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            v.iter().map(clone_literal).collect()
        }
        Ok(ParamSet {
            base: dup(&self.base)?,
            adapt: dup(&self.adapt)?,
            m: dup(&self.m)?,
            v: dup(&self.v)?,
            statics: dup(&self.statics)?,
        })
    }
}

/// Result of one step call.
pub struct StepOut {
    pub loss: f32,
    pub logits: Tensor,
}

pub struct Executable {
    pub meta: ArtifactMeta,
    step: xla::PjRtLoadedExecutable,
    init: xla::PjRtLoadedExecutable,
    n_adapt: usize,
}

impl Executable {
    /// Load + compile the step and init modules for one artifact family.
    pub fn load(client: &Client, artifacts_dir: &Path, meta: &ArtifactMeta) -> Result<Executable> {
        let step = client
            .load_hlo(&artifacts_dir.join(&meta.step_hlo))
            .with_context(|| format!("compiling {}", meta.step_hlo))?;
        let init = client
            .load_hlo(&artifacts_dir.join(&meta.init_hlo))
            .with_context(|| format!("compiling {}", meta.init_hlo))?;
        let n_adapt = meta.inputs_with_role("adapt").len();
        Ok(Executable { meta: meta.clone(), step, init, n_adapt })
    }

    /// Run the init module: seed -> fresh (adapt, m, v) literals.
    pub fn init_state(
        &self,
        seed: i32,
        base: Vec<xla::Literal>,
        statics: Vec<xla::Literal>,
    ) -> Result<ParamSet> {
        let seed_lit = to_literal(&Tensor::scalar_i32(seed))?;
        let out = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let k = self.n_adapt;
        if out.len() != 3 * k {
            bail!("init returned {} tensors, expected {}", out.len(), 3 * k);
        }
        let mut it = out.into_iter();
        let adapt: Vec<_> = it.by_ref().take(k).collect();
        let m: Vec<_> = it.by_ref().take(k).collect();
        let v: Vec<_> = it.collect();
        Ok(ParamSet { base, adapt, m, v, statics })
    }

    /// One fused train/eval step. Mutates `state` (adapt/m/v roll forward).
    pub fn step(
        &self,
        state: &mut ParamSet,
        scalars: StepScalars,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.meta.inputs.len());
        for group in [&state.base, &state.adapt, &state.m, &state.v, &state.statics] {
            args.extend(group.iter());
        }

        // Scalars + batch in the exact order the meta records.
        let mut tail: Vec<xla::Literal> = Vec::new();
        for t in &self.meta.inputs {
            match t.role.as_str() {
                "scalar" => {
                    let v = match t.name.as_str() {
                        "step" => scalars.step,
                        "lr" => scalars.lr,
                        "lr_head" => scalars.lr_head,
                        "wd" => scalars.wd,
                        "scaling" => scalars.scaling,
                        other => bail!("unknown scalar input {other}"),
                    };
                    tail.push(to_literal(&Tensor::scalar(v))?);
                }
                "batch" => {
                    let tensor = batch
                        .get(&t.name)
                        .ok_or_else(|| anyhow!("batch missing tensor '{}'", t.name))?;
                    if tensor.shape != t.shape {
                        bail!("batch '{}' shape {:?}, artifact wants {:?}",
                              t.name, tensor.shape, t.shape);
                    }
                    tail.push(to_literal(tensor)?);
                }
                _ => {}
            }
        }
        let expected =
            args.len() + tail.len();
        if expected != self.meta.inputs.len() {
            bail!("assembled {} args, meta wants {}", expected, self.meta.inputs.len());
        }
        args.extend(tail.iter());

        let out = self.step.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let k = self.n_adapt;
        if out.len() != 3 * k + 2 {
            bail!("step returned {} tensors, expected {}", out.len(), 3 * k + 2);
        }
        let mut it = out.into_iter();
        state.adapt = it.by_ref().take(k).collect();
        state.m = it.by_ref().take(k).collect();
        state.v = it.by_ref().take(k).collect();
        let loss_lit = it.next().unwrap();
        let logits_lit = it.next().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0];
        let logits = from_literal(&logits_lit)?;
        Ok(StepOut { loss, logits })
    }

    /// Pure evaluation: lr = 0 forward pass on a batch; adapt/m/v restored.
    pub fn eval(
        &self,
        state: &mut ParamSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        // lr = 0 leaves adapt unchanged; m/v do roll but we snapshot-restore
        // them so eval is side-effect free.
        let m_save = std::mem::take(&mut state.m);
        let v_save = std::mem::take(&mut state.v);
        state.m = m_save.iter().map(clone_literal).collect::<Result<_>>()?;
        state.v = v_save.iter().map(clone_literal).collect::<Result<_>>()?;
        let out = self.step(
            state,
            StepScalars { step: 1.0, lr: 0.0, lr_head: 0.0, wd: 0.0, scaling },
            batch,
        )?;
        state.m = m_save;
        state.v = v_save;
        Ok(out)
    }

    /// Extract the current adapt tensors as host tensors, keyed by name.
    pub fn adapt_tensors(&self, state: &ParamSet) -> Result<Vec<(String, Tensor)>> {
        let metas = self.meta.inputs_with_role("adapt");
        metas
            .iter()
            .zip(&state.adapt)
            .map(|(m, l)| Ok((m.name.clone(), from_literal(l)?)))
            .collect()
    }

    /// Replace adapt tensors from host tensors (adapter hot-load path).
    pub fn set_adapt(&self, state: &mut ParamSet, tensors: &HashMap<String, Tensor>) -> Result<()> {
        let metas = self.meta.inputs_with_role("adapt");
        let mut new_adapt = Vec::with_capacity(metas.len());
        for m in metas {
            let t = tensors
                .get(&m.name)
                .ok_or_else(|| anyhow!("missing adapt tensor '{}'", m.name))?;
            new_adapt.push(to_literal(t)?);
        }
        state.adapt = new_adapt;
        Ok(())
    }
}

/// The real `xla::Literal` has no Clone; round-trip through host bytes.
#[cfg(feature = "xla-runtime")]
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    to_literal(&from_literal(l)?)
}

/// The compat literal is a host tensor; clone it directly (no shape/dtype
/// re-encode).
#[cfg(not(feature = "xla-runtime"))]
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    Ok(l.clone())
}

/// Run a base-init module: seed -> base tensors (sorted name order).
pub fn run_base_init(
    client: &Client,
    hlo_path: &Path,
    seed: i32,
) -> Result<Vec<xla::Literal>> {
    let exe = client.load_hlo(hlo_path)?;
    let seed_lit = to_literal(&Tensor::scalar_i32(seed))?;
    Ok(exe.execute::<xla::Literal>(&[seed_lit])?[0][0]
        .to_literal_sync()?
        .to_tuple()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_set_try_clone_is_deep() {
        let lit = |v: &[f32]| to_literal(&Tensor::f32(&[v.len()], v.to_vec())).unwrap();
        let ps = ParamSet {
            base: vec![lit(&[1.0, 2.0])],
            adapt: vec![lit(&[3.0])],
            m: vec![lit(&[0.0])],
            v: vec![lit(&[0.0])],
            statics: vec![],
        };
        let mut copy = ps.try_clone().unwrap();
        copy.adapt = vec![lit(&[9.0])];
        // mutating the copy leaves the original untouched
        assert_eq!(ps.adapt[0].to_vec::<f32>().unwrap(), vec![3.0]);
        assert_eq!(copy.base[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert_eq!(copy.statics.len(), 0);
    }
}
