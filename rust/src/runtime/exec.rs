//! Executable wrapper: schema-driven argument assembly + train state.
//!
//! One [`Executable`] owns a compiled step module and its [`ArtifactMeta`].
//! The fused step artifact computes `(adapt', m', v', loss, logits)` from
//! `(base, adapt, m, v, statics, scalars, batch)`; running it with `lr = 0`
//! is a pure eval (the L2 lowering guarantees this — see train.py).
//!
//! State tensors are kept as `xla::Literal`s between steps in a
//! [`LiteralSet`]: the output tuple is decomposed and its adapt/m/v slots
//! become next step's inputs verbatim, so there is no host re-encode in
//! the inner loop. The backend-neutral face of this module is
//! [`XlaEngine`], which implements [`StepEngine`] by converting the host
//! [`ParamSet`](super::engine::ParamSet) to literals at the trait edge —
//! one host↔device round-trip per call, the price of a boundary the host
//! engine doesn't pay. Perf-critical XLA consumers can still use
//! [`Executable`] directly.

use super::artifact::ArtifactMeta;
use super::engine::{ParamSet, StepEngine};
pub use super::engine::{StepOut, StepScalars};
use super::{from_literal, to_literal, xla, Client};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Mutable training state in device-literal form: literals aligned with
/// the meta's per-role order. Internal to the XLA backend — everything
/// above the engine trait holds host tensors.
pub struct LiteralSet {
    pub base: Vec<xla::Literal>,
    pub adapt: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub statics: Vec<xla::Literal>,
}

impl LiteralSet {
    /// Deep copy. Real-runtime literals round-trip through host bytes
    /// ([`clone_literal`]); the compat backend clones host tensors
    /// directly.
    pub fn try_clone(&self) -> Result<LiteralSet> {
        fn dup(v: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            v.iter().map(clone_literal).collect()
        }
        Ok(LiteralSet {
            base: dup(&self.base)?,
            adapt: dup(&self.adapt)?,
            m: dup(&self.m)?,
            v: dup(&self.v)?,
            statics: dup(&self.statics)?,
        })
    }
}

pub struct Executable {
    pub meta: ArtifactMeta,
    step: xla::PjRtLoadedExecutable,
    init: xla::PjRtLoadedExecutable,
    n_adapt: usize,
}

impl Executable {
    /// Load + compile the step and init modules for one artifact family.
    pub fn load(client: &Client, artifacts_dir: &Path, meta: &ArtifactMeta) -> Result<Executable> {
        let step = client
            .load_hlo(&artifacts_dir.join(&meta.step_hlo))
            .with_context(|| format!("compiling {}", meta.step_hlo))?;
        let init = client
            .load_hlo(&artifacts_dir.join(&meta.init_hlo))
            .with_context(|| format!("compiling {}", meta.init_hlo))?;
        let n_adapt = meta.inputs_with_role("adapt").len();
        Ok(Executable { meta: meta.clone(), step, init, n_adapt })
    }

    /// Run the init module: seed -> fresh (adapt, m, v) literals.
    pub fn init_state(
        &self,
        seed: i32,
        base: Vec<xla::Literal>,
        statics: Vec<xla::Literal>,
    ) -> Result<LiteralSet> {
        let seed_lit = to_literal(&Tensor::scalar_i32(seed))?;
        let out = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let k = self.n_adapt;
        if out.len() != 3 * k {
            bail!("init returned {} tensors, expected {}", out.len(), 3 * k);
        }
        let mut it = out.into_iter();
        let adapt: Vec<_> = it.by_ref().take(k).collect();
        let m: Vec<_> = it.by_ref().take(k).collect();
        let v: Vec<_> = it.collect();
        Ok(LiteralSet { base, adapt, m, v, statics })
    }

    /// One fused train/eval step. Mutates `state` (adapt/m/v roll forward).
    pub fn step(
        &self,
        state: &mut LiteralSet,
        scalars: StepScalars,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.meta.inputs.len());
        for group in [&state.base, &state.adapt, &state.m, &state.v, &state.statics] {
            args.extend(group.iter());
        }

        // Scalars + batch in the exact order the meta records.
        let mut tail: Vec<xla::Literal> = Vec::new();
        for t in &self.meta.inputs {
            match t.role.as_str() {
                "scalar" => {
                    let v = match t.name.as_str() {
                        "step" => scalars.step,
                        "lr" => scalars.lr,
                        "lr_head" => scalars.lr_head,
                        "wd" => scalars.wd,
                        "scaling" => scalars.scaling,
                        other => bail!("unknown scalar input {other}"),
                    };
                    tail.push(to_literal(&Tensor::scalar(v))?);
                }
                "batch" => {
                    let tensor = batch
                        .get(&t.name)
                        .ok_or_else(|| anyhow!("batch missing tensor '{}'", t.name))?;
                    if tensor.shape != t.shape {
                        bail!("batch '{}' shape {:?}, artifact wants {:?}",
                              t.name, tensor.shape, t.shape);
                    }
                    tail.push(to_literal(tensor)?);
                }
                _ => {}
            }
        }
        let expected =
            args.len() + tail.len();
        if expected != self.meta.inputs.len() {
            bail!("assembled {} args, meta wants {}", expected, self.meta.inputs.len());
        }
        args.extend(tail.iter());

        let out = self.step.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let k = self.n_adapt;
        if out.len() != 3 * k + 2 {
            bail!("step returned {} tensors, expected {}", out.len(), 3 * k + 2);
        }
        let mut it = out.into_iter();
        state.adapt = it.by_ref().take(k).collect();
        state.m = it.by_ref().take(k).collect();
        state.v = it.by_ref().take(k).collect();
        let loss_lit = it.next().unwrap();
        let logits_lit = it.next().unwrap();
        let loss = loss_lit.to_vec::<f32>()?[0];
        let logits = from_literal(&logits_lit)?;
        Ok(StepOut { loss, logits })
    }

    /// Pure evaluation: lr = 0 forward pass on a batch; adapt/m/v restored.
    pub fn eval(
        &self,
        state: &mut LiteralSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        // lr = 0 leaves adapt unchanged; m/v do roll but we snapshot-restore
        // them so eval is side-effect free.
        let m_save = std::mem::take(&mut state.m);
        let v_save = std::mem::take(&mut state.v);
        state.m = m_save.iter().map(clone_literal).collect::<Result<_>>()?;
        state.v = v_save.iter().map(clone_literal).collect::<Result<_>>()?;
        let out = self.step(
            state,
            StepScalars { step: 1.0, lr: 0.0, lr_head: 0.0, wd: 0.0, scaling },
            batch,
        )?;
        state.m = m_save;
        state.v = v_save;
        Ok(out)
    }

    /// Extract the current adapt tensors as host tensors, keyed by name.
    pub fn adapt_tensors(&self, state: &LiteralSet) -> Result<Vec<(String, Tensor)>> {
        let metas = self.meta.inputs_with_role("adapt");
        metas
            .iter()
            .zip(&state.adapt)
            .map(|(m, l)| Ok((m.name.clone(), from_literal(l)?)))
            .collect()
    }

    /// Replace adapt tensors from host tensors (adapter hot-load path).
    pub fn set_adapt(&self, state: &mut LiteralSet, tensors: &HashMap<String, Tensor>) -> Result<()> {
        let metas = self.meta.inputs_with_role("adapt");
        let mut new_adapt = Vec::with_capacity(metas.len());
        for m in metas {
            let t = tensors
                .get(&m.name)
                .ok_or_else(|| anyhow!("missing adapt tensor '{}'", m.name))?;
            new_adapt.push(to_literal(t)?);
        }
        state.adapt = new_adapt;
        Ok(())
    }
}

/// The real `xla::Literal` has no Clone; round-trip through host bytes.
#[cfg(feature = "xla-runtime")]
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    to_literal(&from_literal(l)?)
}

/// The compat literal is a host tensor; clone it directly (no shape/dtype
/// re-encode).
#[cfg(not(feature = "xla-runtime"))]
pub fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    Ok(l.clone())
}

/// Run a base-init module: seed -> base tensors (sorted name order).
pub fn run_base_init(
    client: &Client,
    hlo_path: &Path,
    seed: i32,
) -> Result<Vec<xla::Literal>> {
    let exe = client.load_hlo(hlo_path)?;
    let seed_lit = to_literal(&Tensor::scalar_i32(seed))?;
    Ok(exe.execute::<xla::Literal>(&[seed_lit])?[0][0]
        .to_literal_sync()?
        .to_tuple()?)
}

// ---------------------------------------------------------------------------
// Engine-trait face of the XLA backend.

/// [`StepEngine`] over a compiled [`Executable`]: host tensors at the
/// trait boundary, literals inside. Each call converts the full state
/// (base/adapt/m/v/statics) to literals and the rolled adapt/m/v back —
/// simple and correct; latency-sensitive XLA loops should keep using
/// [`Executable`] + [`LiteralSet`] directly.
pub struct XlaEngine {
    exe: Executable,
}

impl XlaEngine {
    pub fn load(client: &Client, artifacts_dir: &Path, meta: &ArtifactMeta) -> Result<XlaEngine> {
        Ok(XlaEngine { exe: Executable::load(client, artifacts_dir, meta)? })
    }

    fn to_literals(ts: &[Tensor]) -> Result<Vec<xla::Literal>> {
        ts.iter().map(to_literal).collect()
    }

    fn to_tensors(ls: &[xla::Literal]) -> Result<Vec<Tensor>> {
        ls.iter().map(from_literal).collect()
    }

    fn literal_state(&self, state: &ParamSet) -> Result<LiteralSet> {
        Ok(LiteralSet {
            base: Self::to_literals(&state.base)?,
            adapt: Self::to_literals(&state.adapt)?,
            m: Self::to_literals(&state.m)?,
            v: Self::to_literals(&state.v)?,
            statics: Self::to_literals(&state.statics)?,
        })
    }
}

impl StepEngine for XlaEngine {
    fn id(&self) -> &'static str {
        "xla"
    }

    fn meta(&self) -> &ArtifactMeta {
        &self.exe.meta
    }

    fn init_state(
        &self,
        seed: i32,
        base: Vec<Tensor>,
        statics: Vec<Tensor>,
    ) -> Result<ParamSet> {
        let lit = self.exe.init_state(
            seed,
            Self::to_literals(&base)?,
            Self::to_literals(&statics)?,
        )?;
        Ok(ParamSet {
            base,
            adapt: Self::to_tensors(&lit.adapt)?,
            m: Self::to_tensors(&lit.m)?,
            v: Self::to_tensors(&lit.v)?,
            statics,
        })
    }

    fn step(
        &self,
        state: &mut ParamSet,
        scalars: StepScalars,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        let mut lit = self.literal_state(state)?;
        let out = self.exe.step(&mut lit, scalars, batch)?;
        state.adapt = Self::to_tensors(&lit.adapt)?;
        state.m = Self::to_tensors(&lit.m)?;
        state.v = Self::to_tensors(&lit.v)?;
        Ok(out)
    }

    fn eval(
        &self,
        state: &mut ParamSet,
        scaling: f32,
        batch: &HashMap<String, Tensor>,
    ) -> Result<StepOut> {
        // The literal state is a throwaway copy, so nothing to restore.
        let mut lit = self.literal_state(state)?;
        self.exe.step(
            &mut lit,
            StepScalars { step: 1.0, lr: 0.0, lr_head: 0.0, wd: 0.0, scaling },
            batch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_set_try_clone_is_deep() {
        let lit = |v: &[f32]| to_literal(&Tensor::f32(&[v.len()], v.to_vec())).unwrap();
        let ps = LiteralSet {
            base: vec![lit(&[1.0, 2.0])],
            adapt: vec![lit(&[3.0])],
            m: vec![lit(&[0.0])],
            v: vec![lit(&[0.0])],
            statics: vec![],
        };
        let mut copy = ps.try_clone().unwrap();
        copy.adapt = vec![lit(&[9.0])];
        // mutating the copy leaves the original untouched
        assert_eq!(ps.adapt[0].to_vec::<f32>().unwrap(), vec![3.0]);
        assert_eq!(copy.base[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert_eq!(copy.statics.len(), 0);
    }
}
