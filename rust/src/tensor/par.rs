//! Multi-threaded, cache-blocked f32 GEMM for the host-side hot paths.
//!
//! The serving-time ΔW reconstruction (`fourier::plan`) reduces the sparse
//! inverse DFT to one dense (d1 × 2n)·(2n × d2) matmul, so this kernel is
//! the reconstruction hot loop. It is also the backend of
//! `tensor::linalg::matmul`, replacing the previous single-threaded
//! implementation everywhere dense products are taken host-side.
//!
//! Structure: the output rows are chunked across `std::thread::scope`
//! workers (no thread pool — worker lifetime is one call, which at our
//! sizes is dominated by the O(m k n) loop); each worker runs a k-blocked
//! i-k-j kernel so a K-panel of B stays hot in cache while it streams
//! through its rows of A. Zero A-elements skip the inner row update,
//! preserving the sparse-friendly behavior of the old kernel.
//!
//! Thread-budget coordination: other parallel sections (the serving
//! scheduler's worker pool in `coordinator::scheduler`) claim threads via
//! [`reserve_threads`]; [`num_threads`] divides the leftover threads
//! evenly among the reserved workers, so a GEMM running *inside* a serve
//! worker gets only its fair share (single-threaded on small hosts)
//! instead of spawning another full complement of threads per worker.

use super::Tensor;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// K-panel height for the blocked kernel: 256 rows of B at n ≤ 2048 f32
/// columns is ≤ 2 MB, comfortably L2-resident on anything current.
const KC: usize = 256;

/// Below this many multiply-adds the scoped-thread setup costs more than
/// the whole product; run single-threaded.
const PAR_THRESHOLD: usize = 1 << 16;

/// Threads currently claimed by non-matmul parallel sections (the serving
/// scheduler's worker pool). See [`reserve_threads`].
static RESERVED: AtomicUsize = AtomicUsize::new(0);

/// RAII claim of `n` threads from the process-wide budget. While the
/// reservation is alive, [`num_threads`] hands parallel sections only an
/// even share of the unreserved threads, so GEMMs nested under serve
/// workers don't oversubscribe the machine (serve workers × matmul
/// workers). Dropping the reservation returns the threads.
#[derive(Debug)]
pub struct ThreadReservation {
    n: usize,
}

/// Claim `n` threads from the matmul budget for the reservation's lifetime.
pub fn reserve_threads(n: usize) -> ThreadReservation {
    RESERVED.fetch_add(n, Ordering::SeqCst);
    ThreadReservation { n }
}

impl Drop for ThreadReservation {
    fn drop(&mut self) {
        RESERVED.fetch_sub(self.n, Ordering::SeqCst);
    }
}

/// Threads currently reserved by other parallel sections.
pub fn reserved_threads() -> usize {
    RESERVED.load(Ordering::SeqCst)
}

/// Worker count for parallel sections. With no reservations outstanding:
/// the physical parallelism. While `r` threads are reserved, each reserved
/// thread is a worker that may itself run a nested parallel section
/// concurrently, so the leftover `avail - r` threads are shared evenly
/// among them — total compute threads stay ≈ `avail` instead of
/// `r × (avail - r)`. Floored at 1 (on hosts where `r ≥ avail`, nested
/// sections run single-threaded).
pub fn num_threads() -> usize {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let reserved = RESERVED.load(Ordering::SeqCst);
    if reserved == 0 {
        avail
    } else {
        (avail.saturating_sub(reserved) / reserved).max(1)
    }
}

/// C(m×n) = A(m×k) · B(k×n), all row-major f32 slices.
///
/// Panics if the slice lengths disagree with the dims (programmer error —
/// the `Tensor`-level wrappers do the user-facing validation).
pub fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A length vs {m}x{k}");
    assert_eq!(b.len(), k * n, "B length vs {k}x{n}");
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let work = m.saturating_mul(k).saturating_mul(n);
    let threads = if work < PAR_THRESHOLD { 1 } else { num_threads().min(m) };
    if threads <= 1 {
        matmul_rows(a, b, &mut c, m, k, n);
    } else {
        let rows_per = m.div_ceil(threads);
        std::thread::scope(|s| {
            for (ti, c_chunk) in c.chunks_mut(rows_per * n).enumerate() {
                let rows = c_chunk.len() / n;
                let a_chunk = &a[ti * rows_per * k..ti * rows_per * k + rows * k];
                s.spawn(move || matmul_rows(a_chunk, b, c_chunk, rows, k, n));
            }
        });
    }
    c
}

/// Blocked i-k-j kernel over a contiguous row range: C += A · B with C
/// pre-zeroed by the caller.
fn matmul_rows(a: &[f32], b: &[f32], c: &mut [f32], rows: usize, k: usize, n: usize) {
    for kk in (0..k).step_by(KC) {
        let kend = (kk + KC).min(k);
        for i in 0..rows {
            let a_row = &a[i * k..(i + 1) * k];
            let c_row = &mut c[i * n..(i + 1) * n];
            for (p, &aip) in a_row.iter().enumerate().take(kend).skip(kk) {
                if aip == 0.0 {
                    continue;
                }
                let b_row = &b[p * n..(p + 1) * n];
                for (cj, &bj) in c_row.iter_mut().zip(b_row) {
                    *cj += aip * bj;
                }
            }
        }
    }
}

/// Tensor-level wrapper: C = A @ B with A: [m, k], B: [k, n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(a.rank() == 2 && b.rank() == 2, "matmul wants rank-2 tensors");
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    anyhow::ensure!(k == k2, "matmul inner dims {k} vs {k2}");
    let c = matmul_f32(a.as_f32()?, b.as_f32()?, m, k, n);
    Ok(Tensor::f32(&[m, n], c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    /// Naive reference for cross-checking.
    fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_reference_on_random_shapes() {
        let mut rng = Rng::new(0x6E88);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 64, 33), (128, 300, 64), (64, 1024, 96)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let got = matmul_f32(&a, &b, m, k, n);
            let want = matmul_ref(&a, &b, m, k, n);
            let max = got.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            // identical summation order per element => tight tolerance
            assert!(max < 1e-3, "({m},{k},{n}) max diff {max}");
        }
    }

    #[test]
    fn large_enough_to_cross_the_thread_threshold() {
        let mut rng = Rng::new(7);
        let (m, k, n) = (97, 120, 80); // m not divisible by thread count
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let got = matmul_f32(&a, &b, m, k, n);
        let want = matmul_ref(&a, &b, m, k, n);
        let max = got.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-3, "max diff {max}");
    }

    #[test]
    fn thread_reservation_floors_at_one_and_restores() {
        let before = reserved_threads();
        {
            let _r = reserve_threads(1000);
            assert!(reserved_threads() >= before + 1000);
            assert_eq!(num_threads(), 1, "a huge reservation must floor the budget at 1");
        }
        // Other tests may hold small reservations concurrently; ours (1000)
        // must be returned on drop.
        assert!(reserved_threads() < before + 1000);
    }

    #[test]
    fn matmul_is_correct_under_reservation() {
        let _r = reserve_threads(1000); // force the single-threaded path
        let mut rng = Rng::new(0x77);
        let (m, k, n) = (33, 70, 41);
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let got = matmul_f32(&a, &b, m, k, n);
        let want = matmul_ref(&a, &b, m, k, n);
        let max = got.iter().zip(&want).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-3, "max diff {max}");
    }

    #[test]
    fn empty_dims_yield_zeros() {
        assert!(matmul_f32(&[], &[], 0, 0, 4).is_empty());
        assert_eq!(matmul_f32(&[], &[], 2, 0, 2), vec![0.0; 4]);
    }

    #[test]
    fn tensor_wrapper_checks_shapes() {
        let a = Tensor::f32(&[2, 3], vec![1.0; 6]);
        let b = Tensor::f32(&[4, 2], vec![1.0; 8]);
        assert!(matmul(&a, &b).is_err());
    }
}
