//! Host-side tensor substrate: a small dense f32/i32 tensor with shape
//! metadata, plus linear algebra (`linalg`), the multi-threaded blocked
//! GEMM backing it (`par`), and the deterministic PRNG (`rng`) used by
//! every data generator.

pub mod linalg;
pub mod par;
pub mod rng;

use anyhow::{bail, Result};

/// Element type of a [`Tensor`]. Mirrors the two dtypes the artifact ABI
/// uses (`f32` weights/activations, `i32` token ids / labels / entries).
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} vs len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn zeros_i32(shape: &[usize]) -> Tensor {
        Tensor::i32(shape, vec![0; shape.iter().product()])
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::i32(&[], vec![v])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Payload size in bytes (both dtypes are 4-byte elements).
    pub fn byte_size(&self) -> usize {
        self.len() * 4
    }

    pub fn dtype(&self) -> &'static str {
        match self.data {
            Data::F32(_) => "f32",
            Data::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            Data::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            Data::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        match &self.data {
            Data::F32(v) => v[i * self.shape[1] + j],
            Data::I32(v) => v[i * self.shape[1] + j] as f32,
        }
    }

    /// Elementwise in-place add (shape-checked); used by the host-side
    /// delta-merge path.
    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let o = other.as_f32()?;
        for (a, b) in self.as_f32_mut()?.iter_mut().zip(o) {
            *a += *b;
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) -> Result<()> {
        for a in self.as_f32_mut()? {
            *a *= s;
        }
        Ok(())
    }

    /// Max absolute difference against another tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max))
    }

    pub fn frob_norm(&self) -> f32 {
        match &self.data {
            Data::F32(v) => v.iter().map(|x| x * x).sum::<f32>().sqrt(),
            Data::I32(v) => v.iter().map(|&x| (x as f32) * (x as f32)).sum::<f32>().sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), "f32");
    }

    #[test]
    #[should_panic]
    fn shape_len_mismatch_panics() {
        Tensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = Tensor::f32(&[2], vec![1.0, 2.0]);
        let b = Tensor::f32(&[2], vec![10.0, 20.0]);
        a.add_assign(&b).unwrap();
        a.scale(0.5).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[5.5, 11.0]);
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = Tensor::zeros_i32(&[2]);
        assert!(t.as_f32().is_err());
        assert!(Tensor::zeros(&[2]).as_i32().is_err());
    }
}
