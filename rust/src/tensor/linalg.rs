//! Dense linear algebra for the host-side substrates: matmul (delegating
//! to the multi-threaded blocked kernel in [`super::par`]), Householder QR
//! (random orthogonal basis generation for the Table 6 ablation), and
//! small helpers shared by the Fourier module and tests.

use super::Tensor;
use anyhow::Result;

/// C = A @ B with A: [m, k], B: [k, n]. Backed by the cache-blocked,
/// multi-threaded kernel in [`super::par`] (small products stay on the
/// calling thread).
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    super::par::matmul(a, b)
}

pub fn transpose(a: &Tensor) -> Result<Tensor> {
    let (m, n) = (a.shape[0], a.shape[1]);
    let av = a.as_f32()?;
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = av[i * n + j];
        }
    }
    Ok(Tensor::f32(&[n, m], out))
}

/// Householder QR of a square matrix; returns Q (orthogonal).
///
/// Used to produce the "orthogonal basis" for the paper's Table 6 ablation:
/// Q from the QR of a Gaussian matrix is Haar-distributed (up to sign
/// convention, which we fix so diag(R) >= 0).
pub fn qr_q(a: &Tensor) -> Result<Tensor> {
    let n = a.shape[0];
    anyhow::ensure!(a.shape[1] == n, "qr_q wants square, got {:?}", a.shape);
    let mut r: Vec<f64> = a.as_f32()?.iter().map(|&x| x as f64).collect();
    let mut q: Vec<f64> = vec![0.0; n * n];
    for i in 0..n {
        q[i * n + i] = 1.0;
    }
    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut norm = 0.0;
        for i in k..n {
            norm += r[i * n + k] * r[i * n + k];
        }
        let norm = norm.sqrt();
        if norm < 1e-12 {
            continue;
        }
        let alpha = if r[k * n + k] > 0.0 { -norm } else { norm };
        let mut v = vec![0.0; n];
        v[k] = r[k * n + k] - alpha;
        for i in (k + 1)..n {
            v[i] = r[i * n + k];
        }
        let vtv: f64 = v[k..].iter().map(|x| x * x).sum();
        if vtv < 1e-24 {
            continue;
        }
        // R <- (I - 2 v v^T / v^T v) R
        for j in k..n {
            let dot: f64 = (k..n).map(|i| v[i] * r[i * n + j]).sum();
            let c = 2.0 * dot / vtv;
            for i in k..n {
                r[i * n + j] -= c * v[i];
            }
        }
        // Q <- Q (I - 2 v v^T / v^T v)
        for i in 0..n {
            let dot: f64 = (k..n).map(|j| v[j] * q[i * n + j]).sum();
            let c = 2.0 * dot / vtv;
            for j in k..n {
                q[i * n + j] -= c * v[j];
            }
        }
    }
    // Sign fix: make diag(R) non-negative so Q is unique.
    for k in 0..n {
        if r[k * n + k] < 0.0 {
            for i in 0..n {
                q[i * n + k] = -q[i * n + k];
            }
        }
    }
    Ok(Tensor::f32(&[n, n], q.iter().map(|&x| x as f32).collect()))
}

/// Thin QR of a tall matrix A \[m, r\] (m >= r): returns Q \[m, r\] with
/// orthonormal columns spanning range(A). Modified Gram–Schmidt in f64
/// with a re-orthogonalization pass (the classic "twice is enough" fix).
/// Numerically-dead columns (rank-deficient input) are left as zero
/// columns rather than failing — callers doing subspace iteration just
/// get a smaller effective rank.
pub fn qr_thin(a: &Tensor) -> Result<Tensor> {
    anyhow::ensure!(a.shape.len() == 2, "qr_thin wants a matrix, got {:?}", a.shape);
    let (m, r) = (a.shape[0], a.shape[1]);
    anyhow::ensure!(m >= r, "qr_thin wants tall/square input, got {:?}", a.shape);
    let av = a.as_f32()?;
    // Column-major working copy in f64.
    let mut q: Vec<f64> = vec![0.0; m * r];
    for i in 0..m {
        for j in 0..r {
            q[j * m + i] = av[i * r + j] as f64;
        }
    }
    for j in 0..r {
        // Two MGS passes of projection against the already-finished columns.
        for _pass in 0..2 {
            for k in 0..j {
                let dot: f64 =
                    (0..m).map(|i| q[k * m + i] * q[j * m + i]).sum();
                for i in 0..m {
                    q[j * m + i] -= dot * q[k * m + i];
                }
            }
        }
        let norm: f64 = (0..m).map(|i| q[j * m + i] * q[j * m + i]).sum::<f64>().sqrt();
        if norm < 1e-12 {
            for i in 0..m {
                q[j * m + i] = 0.0;
            }
            continue;
        }
        for i in 0..m {
            q[j * m + i] /= norm;
        }
    }
    let mut out = vec![0.0f32; m * r];
    for i in 0..m {
        for j in 0..r {
            out[i * r + j] = q[j * m + i] as f32;
        }
    }
    Ok(Tensor::f32(&[m, r], out))
}

/// Pearson correlation coefficient of two equal-length slices.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..a.len() {
        let (da, db) = (a[i] as f64 - ma, b[i] as f64 - mb);
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Spearman rank correlation (ties get average ranks).
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

fn ranks(x: &[f32]) -> Vec<f32> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&i, &j| x[i].partial_cmp(&x[j]).unwrap());
    let mut out = vec![0.0f32; x.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f32 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn matmul_known() {
        let a = Tensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.as_f32().unwrap(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = transpose(&transpose(&a).unwrap()).unwrap();
        assert_eq!(a, t);
    }

    #[test]
    fn qr_gives_orthogonal_q() {
        let mut rng = Rng::new(5);
        let n = 24;
        let a = Tensor::f32(&[n, n], rng.normal_vec(n * n, 1.0));
        let q = qr_q(&a).unwrap();
        let qtq = matmul(&transpose(&q).unwrap(), &q).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at2(i, j) - want).abs() < 1e-4, "({i},{j}) {}", qtq.at2(i, j));
            }
        }
    }

    #[test]
    fn qr_thin_orthonormal_and_spans_input() {
        let mut rng = Rng::new(17);
        let (m, r) = (40, 6);
        let a = Tensor::f32(&[m, r], rng.normal_vec(m * r, 1.0));
        let q = qr_thin(&a).unwrap();
        assert_eq!(q.shape, vec![m, r]);
        let qtq = matmul(&transpose(&q).unwrap(), &q).unwrap();
        for i in 0..r {
            for j in 0..r {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at2(i, j) - want).abs() < 1e-5, "({i},{j}) {}", qtq.at2(i, j));
            }
        }
        // Q Qᵀ A == A (Q spans the full-rank input's column space).
        let proj = matmul(&q, &matmul(&transpose(&q).unwrap(), &a).unwrap()).unwrap();
        assert!(proj.max_abs_diff(&a).unwrap() < 1e-4);
    }

    #[test]
    fn qr_thin_zeroes_dependent_columns() {
        // Second column = 2x first: its orthogonalized residual is dead.
        let a = Tensor::f32(&[3, 2], vec![1., 2., 0., 0., 1., 2.]);
        let q = qr_thin(&a).unwrap();
        let qv = q.as_f32().unwrap();
        for i in 0..3 {
            assert_eq!(qv[i * 2 + 1], 0.0, "dependent column must be zeroed");
        }
        let n0: f32 = (0..3).map(|i| qv[i * 2] * qv[i * 2]).sum();
        assert!((n0 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone => rho = 1
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }
}
