//! Deterministic PRNG for data generation and initialization.
//!
//! SplitMix64 seeding + xoshiro256** core (Blackman & Vigna). No external
//! crates: the offline vendor set has no `rand`, and we want bit-identical
//! datasets across runs/platforms anyway — every experiment in
//! EXPERIMENTS.md records its seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the xoshiro state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Independent child stream (for per-task / per-seed substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xD1342543DE82EF95))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our sizes).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo) as usize) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_distinct: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        let mut t = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(9);
        let sel = r.choose_distinct(100, 40);
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
        assert!(sel.iter().all(|&i| i < 100));
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }
}
