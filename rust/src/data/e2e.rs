//! E2E-NLG-like data-to-text task (Table 3).
//!
//! Mirrors the E2E challenge structure: a meaning representation (MR) of
//! restaurant slots is linearized as the prompt; the target is a natural-
//! language utterance realizing those slots. Several surface templates per
//! MR provide the *multiple references* the E2E metrics (BLEU / NIST /
//! METEOR / ROUGE-L / CIDEr) are designed for.
//!
//! Sequence layout (decoder, T = 48):
//!   BOS  name[x] food[y] price[z] area[w] rating[v]  SEP  utterance  EOS
//! Loss mask covers only the utterance (+EOS), exactly like fine-tuning
//! GPT-2 on E2E with the prompt masked out.

use super::vocab::{vocab, Class, BOS, EOS, SEP};
use super::{Label, TextExample};
use crate::tensor::rng::Rng;

/// One meaning representation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mr {
    pub name: i32,
    pub food: i32,
    pub price: i32,
    pub area: i32,
    pub rating: i32,
}

impl Mr {
    pub fn sample(rng: &mut Rng) -> Mr {
        let v = vocab();
        let p = |c: Class, rng: &mut Rng| {
            let ids = v.ids_of(c);
            ids[rng.below(ids.len())]
        };
        Mr {
            name: p(Class::Name, rng),
            food: p(Class::Food, rng),
            price: p(Class::Price, rng),
            area: p(Class::Area, rng),
            rating: p(Class::Rating, rng),
        }
    }

    /// Linearized prompt tokens (the "table").
    pub fn prompt(&self) -> Vec<i32> {
        vec![BOS, self.name, self.food, self.price, self.area, self.rating, SEP]
    }

    /// All reference realizations (each a token sequence, EOS-terminated).
    pub fn references(&self) -> Vec<Vec<i32>> {
        let v = vocab();
        let the = v.ids_of(Class::Determiner)[0];
        let is = v.ids_of(Class::Verb)[0];
        let place = v.ids_of(Class::Noun)
            .into_iter()
            .find(|&id| v.word(id) == "place")
            .unwrap();
        // Three template families, mirroring E2E's human-reference variety.
        let t1 = vec![
            self.name, is, the, self.price, self.food, place, self.area, self.rating, EOS,
        ];
        let t2 = vec![
            the, self.food, place, self.name, is, self.price, self.rating, self.area, EOS,
        ];
        let t3 = vec![
            self.name, is, the, self.rating, self.food, place, self.price, self.area, EOS,
        ];
        vec![t1, t2, t3]
    }

    /// One training example: prompt + a sampled reference, LM-shifted.
    pub fn example(&self, rng: &mut Rng, seqlen: usize) -> TextExample {
        let refs = self.references();
        let target_seq = &refs[rng.below(refs.len())];
        let mut tokens = self.prompt();
        let prompt_len = tokens.len();
        tokens.extend(target_seq);
        // next-token LM: y[t] = x[t+1], mask on positions predicting the
        // utterance (from the SEP position through EOS-1).
        let mut y = tokens[1..].to_vec();
        y.push(0);
        let mut mask = vec![0.0f32; tokens.len()];
        for m in mask.iter_mut().take(tokens.len() - 1).skip(prompt_len - 1) {
            *m = 1.0;
        }
        tokens.truncate(seqlen);
        y.truncate(seqlen);
        mask.truncate(seqlen);
        TextExample { tokens, label: Label::Seq { target: y, mask } }
    }
}

/// Deterministic dataset of MRs; train/val/test use disjoint MR streams.
pub fn split(split: &str, count: usize, seed: u64) -> Vec<Mr> {
    let tag = match split {
        "train" => 0x11,
        "val" => 0x22,
        "test" => 0x33,
        other => panic!("unknown split {other}"),
    };
    let mut rng = Rng::new(seed ^ 0xE2E0).fork(tag);
    (0..count).map(|_| Mr::sample(&mut rng)).collect()
}

/// Training examples for a list of MRs.
pub fn examples(mrs: &[Mr], seqlen: usize, seed: u64) -> Vec<TextExample> {
    let mut rng = Rng::new(seed ^ 0xE2E1);
    mrs.iter().map(|mr| mr.example(&mut rng, seqlen)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_and_references_are_well_formed() {
        let mut rng = Rng::new(1);
        let mr = Mr::sample(&mut rng);
        assert_eq!(mr.prompt().len(), 7);
        for r in mr.references() {
            assert_eq!(*r.last().unwrap(), EOS);
            assert!(r.contains(&mr.name));
            assert!(r.contains(&mr.food));
            assert!(r.contains(&mr.price));
        }
    }

    #[test]
    fn references_differ_in_word_order() {
        let mut rng = Rng::new(2);
        let mr = Mr::sample(&mut rng);
        let refs = mr.references();
        assert_ne!(refs[0], refs[1]);
        assert_ne!(refs[1], refs[2]);
    }

    #[test]
    fn example_mask_covers_only_utterance() {
        let mut rng = Rng::new(3);
        let mr = Mr::sample(&mut rng);
        let ex = mr.example(&mut rng, 48);
        if let Label::Seq { target, mask } = &ex.label {
            assert_eq!(target.len(), ex.tokens.len());
            // prompt positions (before SEP) carry no loss except the one
            // predicting the first utterance token
            let sep_pos = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            assert_eq!(mask[..sep_pos - 1], vec![0.0; sep_pos - 1][..]);
            assert!(mask[sep_pos] > 0.0);
            // masked positions' targets are the utterance tokens
            let masked: usize = mask.iter().map(|&m| m as usize).sum();
            assert_eq!(masked, mr.references()[0].len());
        } else {
            panic!("expected Seq label");
        }
    }

    #[test]
    fn splits_disjoint() {
        let tr = split("train", 200, 5);
        let te = split("test", 50, 5);
        let dup = te.iter().filter(|m| tr.contains(m)).count();
        assert!(dup <= 2, "{dup} test MRs leak into train");
    }

    #[test]
    fn fits_decoder_window() {
        let mrs = split("train", 100, 7);
        for ex in examples(&mrs, 48, 7) {
            assert!(ex.tokens.len() <= 48);
        }
    }
}
