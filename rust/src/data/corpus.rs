//! Pretraining streams: the "broad" data the sim base models are trained on
//! before any fine-tuning, covering the grammar of every downstream task.
//!
//! * Encoder: masked-token prediction (15% of positions, MLM-style).
//! * Decoder: plain next-token LM over the same sentence distribution plus
//!   E2E prompts and instruction traces (so fine-tuning starts from a
//!   competent base, as with real GPT-2 / LLaMA checkpoints).

use super::vocab::{vocab, Class, BOS, CLS, EOS, MASK};
use super::{Label, TextExample};
use crate::tensor::rng::Rng;

/// A generic grammatical sentence mixing all word classes.
pub fn sentence(rng: &mut Rng, len: usize) -> Vec<i32> {
    let v = vocab();
    let mut toks = Vec::with_capacity(len);
    let classes = [
        Class::Determiner,
        Class::NeutralAdj,
        Class::Noun,
        Class::Verb,
        Class::Adverb,
        Class::PosAdj,
        Class::NegAdj,
        Class::Name,
        Class::Food,
        Class::Price,
        Class::Area,
        Class::Rating,
        Class::Number,
        Class::Op,
        Class::Question,
        Class::Negation,
        Class::Filler,
    ];
    // Weighted towards the content classes the tasks use, with fillers so
    // the whole embedding table trains.
    let weights = [8.0, 6.0, 12.0, 10.0, 4.0, 5.0, 5.0, 3.0, 3.0, 2.0, 2.0, 2.0, 4.0, 2.0, 2.0, 2.0, 8.0];
    for _ in 0..len {
        let c = classes[rng.weighted(&weights)];
        let ids = v.ids_of(c);
        toks.push(ids[rng.below(ids.len())]);
    }
    toks
}

/// Encoder MLM example: x has MASK at ~15% of positions, y holds the
/// original ids, mask selects the masked positions for the loss.
pub fn mlm_example(rng: &mut Rng, seqlen: usize) -> TextExample {
    let mut x = vec![CLS];
    x.extend(sentence(rng, seqlen - 1));
    let y = x.clone();
    let mut mask = vec![0.0f32; seqlen];
    for i in 1..seqlen {
        if rng.chance(0.15) {
            x[i] = MASK;
            mask[i] = 1.0;
        }
    }
    if mask.iter().all(|&m| m == 0.0) {
        x[1] = MASK;
        mask[1] = 1.0;
    }
    TextExample { tokens: x, label: Label::Seq { target: y, mask } }
}

/// Decoder LM example: next-token prediction over a sentence or a task-
/// format trace (20% E2E-shaped, 20% instruction-shaped, 60% prose).
pub fn lm_example(rng: &mut Rng, seqlen: usize) -> TextExample {
    let roll = rng.f64();
    let mut x = if roll < 0.2 {
        let mr = super::e2e::Mr::sample(rng);
        let mut t = mr.prompt();
        let refs = mr.references();
        t.extend(&refs[rng.below(refs.len())]);
        t
    } else if roll < 0.4 {
        let q = super::instruct::Question::sample(rng, &super::instruct::Op::ALL);
        let mut t = q.prompt();
        t.extend(q.answer());
        t
    } else {
        let mut t = vec![BOS];
        t.extend(sentence(rng, seqlen - 2));
        t.push(EOS);
        t
    };
    x.truncate(seqlen);
    let mut y = x[1..].to_vec();
    y.push(0);
    let mut mask = vec![1.0f32; x.len()];
    *mask.last_mut().unwrap() = 0.0;
    TextExample { tokens: x, label: Label::Seq { target: y, mask } }
}

pub fn mlm_set(count: usize, seqlen: usize, seed: u64) -> Vec<TextExample> {
    let mut rng = Rng::new(seed ^ 0x313A);
    (0..count).map(|_| mlm_example(&mut rng, seqlen)).collect()
}

pub fn lm_set(count: usize, seqlen: usize, seed: u64) -> Vec<TextExample> {
    let mut rng = Rng::new(seed ^ 0x1313);
    (0..count).map(|_| lm_example(&mut rng, seqlen)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::SEP;

    #[test]
    fn mlm_masks_roughly_15_percent() {
        let exs = mlm_set(100, 32, 1);
        let total: f32 = exs
            .iter()
            .map(|e| match &e.label {
                Label::Seq { mask, .. } => mask.iter().sum::<f32>(),
                _ => 0.0,
            })
            .sum();
        let frac = total / (100.0 * 31.0);
        assert!((0.10..0.22).contains(&frac), "mask fraction {frac}");
    }

    #[test]
    fn mlm_target_restores_original() {
        let mut rng = Rng::new(2);
        let ex = mlm_example(&mut rng, 16);
        if let Label::Seq { target, mask } = &ex.label {
            for i in 0..16 {
                if mask[i] > 0.0 {
                    assert_eq!(ex.tokens[i], MASK);
                    assert_ne!(target[i], MASK);
                } else {
                    assert_eq!(ex.tokens[i], target[i]);
                }
            }
        }
    }

    #[test]
    fn lm_y_is_shifted_x() {
        let exs = lm_set(20, 48, 3);
        for e in &exs {
            if let Label::Seq { target, .. } = &e.label {
                for i in 0..e.tokens.len() - 1 {
                    assert_eq!(target[i], e.tokens[i + 1]);
                }
            }
        }
    }

    #[test]
    fn lm_mixes_task_formats() {
        let exs = lm_set(200, 48, 4);
        let with_sep = exs.iter().filter(|e| e.tokens.contains(&SEP)).count();
        assert!(with_sep > 40, "only {with_sep}/200 contain task formatting");
    }

    #[test]
    fn sentences_use_broad_vocab() {
        let mut rng = Rng::new(7);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            for t in sentence(&mut rng, 20) {
                seen.insert(t);
            }
        }
        assert!(seen.len() > 300, "vocabulary coverage {} too low", seen.len());
    }
}
