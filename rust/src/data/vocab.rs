//! Shared 1000-token vocabulary with word classes.
//!
//! The synthetic grammar's terminals are organized into part-of-speech /
//! semantic classes; every text task draws from the same vocabulary so the
//! encoder/decoder pretraining distribution covers the fine-tuning tasks
//! (as real-world pretraining does). Ids are stable across runs: the
//! vocabulary is constructed deterministically at first use.

use std::sync::OnceLock;

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const MASK: i32 = 3;
pub const BOS: i32 = 4;
pub const EOS: i32 = 5;
pub const FIRST_WORD: i32 = 6;

/// Word classes used by the grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    PosAdj,
    NegAdj,
    NeutralAdj,
    Noun,
    Verb,
    Adverb,
    Determiner,
    Negation,
    Name,
    Food,
    Price,
    Area,
    Rating,
    Question,
    Number,
    Op,
    Filler,
}

pub struct Vocab {
    words: Vec<(&'static str, Class)>,
}

static VOCAB: OnceLock<Vocab> = OnceLock::new();

pub fn vocab() -> &'static Vocab {
    VOCAB.get_or_init(Vocab::build)
}

impl Vocab {
    pub fn size(&self) -> usize {
        FIRST_WORD as usize + self.words.len()
    }

    /// Token id -> surface string (specials included).
    pub fn word(&self, id: i32) -> &'static str {
        match id {
            PAD => "<pad>",
            CLS => "<cls>",
            SEP => "<sep>",
            MASK => "<mask>",
            BOS => "<bos>",
            EOS => "<eos>",
            _ => self.words[(id - FIRST_WORD) as usize].0,
        }
    }

    pub fn class_of(&self, id: i32) -> Option<Class> {
        if id < FIRST_WORD || (id - FIRST_WORD) as usize >= self.words.len() {
            return None;
        }
        Some(self.words[(id - FIRST_WORD) as usize].1)
    }

    /// All token ids of a class.
    pub fn ids_of(&self, class: Class) -> Vec<i32> {
        self.words
            .iter()
            .enumerate()
            .filter(|(_, (_, c))| *c == class)
            .map(|(i, _)| i as i32 + FIRST_WORD)
            .collect()
    }

    pub fn detok(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i != PAD)
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    fn build() -> Vocab {
        let mut words: Vec<(&'static str, Class)> = Vec::new();
        let mut add = |list: &[&'static str], class: Class, words: &mut Vec<(&'static str, Class)>| {
            for w in list {
                words.push((w, class));
            }
        };
        add(&["good", "great", "excellent", "wonderful", "amazing", "superb",
              "delightful", "fantastic", "charming", "pleasant", "brilliant",
              "lovely", "stellar", "impressive", "enjoyable", "satisfying"],
            Class::PosAdj, &mut words);
        add(&["bad", "terrible", "awful", "horrible", "dreadful", "poor",
              "disappointing", "mediocre", "bland", "boring", "unpleasant",
              "dull", "weak", "forgettable", "tedious", "lousy"],
            Class::NegAdj, &mut words);
        add(&["red", "blue", "green", "small", "large", "old", "new", "quiet",
              "busy", "modern", "classic", "local", "famous", "simple"],
            Class::NeutralAdj, &mut words);
        add(&["movie", "film", "book", "story", "meal", "service", "plot",
              "acting", "music", "place", "city", "river", "dog", "cat",
              "house", "garden", "street", "market", "teacher", "student",
              "doctor", "artist", "game", "song", "show", "paper", "idea",
              "coffee", "bread", "table", "window", "door", "tree", "bird",
              "car", "train", "journey", "evening", "morning", "dinner"],
            Class::Noun, &mut words);
        add(&["is", "was", "seems", "feels", "looks", "sounds", "runs",
              "walks", "reads", "writes", "sings", "plays", "visits",
              "serves", "offers", "makes", "tells", "shows", "finds", "keeps"],
            Class::Verb, &mut words);
        add(&["very", "quite", "really", "truly", "rather", "fairly",
              "extremely", "remarkably", "surprisingly", "genuinely"],
            Class::Adverb, &mut words);
        add(&["the", "a", "this", "that", "every", "some"], Class::Determiner, &mut words);
        add(&["not", "never", "hardly", "barely"], Class::Negation, &mut words);
        add(&["alimento", "bibimbap", "cascade", "delmonte", "eastgate",
              "fortuna", "galleria", "harvest", "ironwood", "juniper",
              "kestrel", "lantern", "meridian", "nectar", "orchid", "pavilion"],
            Class::Name, &mut words);
        add(&["italian", "chinese", "french", "indian", "japanese", "mexican",
              "thai", "greek", "spanish", "korean", "fusion", "vegan"],
            Class::Food, &mut words);
        add(&["cheap", "moderate", "expensive", "premium"], Class::Price, &mut words);
        add(&["centre", "riverside", "uptown", "suburbs", "harbour", "oldtown"],
            Class::Area, &mut words);
        add(&["onestar", "twostar", "threestar", "fourstar", "fivestar"],
            Class::Rating, &mut words);
        add(&["what", "where", "who", "when", "which", "how"], Class::Question, &mut words);
        add(&["zero", "one", "two", "three", "four", "five", "six", "seven",
              "eight", "nine", "ten", "eleven", "twelve", "thirteen",
              "fourteen", "fifteen"],
            Class::Number, &mut words);
        add(&["reverse", "sort", "copy", "count", "first", "last", "add",
              "swap", "unique", "repeat"],
            Class::Op, &mut words);
        // Filler words pad the vocabulary to a realistic size; pretraining
        // uses them so embeddings of rare ids are still trained.
        const FILLERS: usize = 1000;
        static FILLER_NAMES: OnceLock<Vec<String>> = OnceLock::new();
        let fillers = FILLER_NAMES.get_or_init(|| {
            (0..FILLERS).map(|i| format!("w{i:03}")).collect()
        });
        for f in fillers {
            if words.len() + FIRST_WORD as usize >= 1000 {
                break;
            }
            // leak: 'static strings for a fixed small vocabulary
            words.push((Box::leak(f.clone().into_boxed_str()), Class::Filler));
        }
        assert_eq!(words.len() + FIRST_WORD as usize, 1000);
        Vocab { words }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_exactly_1000() {
        assert_eq!(vocab().size(), 1000);
    }

    #[test]
    fn classes_nonempty_and_disjoint_ids() {
        let v = vocab();
        for c in [Class::PosAdj, Class::NegAdj, Class::Noun, Class::Verb,
                  Class::Name, Class::Food, Class::Price, Class::Area,
                  Class::Rating, Class::Number, Class::Op] {
            assert!(!v.ids_of(c).is_empty(), "{c:?} empty");
        }
        let pos = v.ids_of(Class::PosAdj);
        let neg = v.ids_of(Class::NegAdj);
        assert!(pos.iter().all(|i| !neg.contains(i)));
    }

    #[test]
    fn word_id_roundtrip() {
        let v = vocab();
        let ids = v.ids_of(Class::Name);
        assert_eq!(v.word(ids[0]), "alimento");
        assert_eq!(v.class_of(ids[0]), Some(Class::Name));
        assert_eq!(v.class_of(PAD), None);
    }

    #[test]
    fn detok_skips_pad() {
        let v = vocab();
        let s = v.detok(&[CLS, v.ids_of(Class::Noun)[0], PAD, PAD]);
        assert_eq!(s, "<cls> movie");
    }
}
