//! Eight procedural image datasets (Table 5) + an ImageNet-21k-sim
//! pretraining mixture.
//!
//! Each dataset mirrors its paper counterpart's class count and difficulty
//! character:
//!
//! | sim name      | classes | generator family            | mirrors     |
//! |---------------|---------|-----------------------------|-------------|
//! | pets37        |      37 | blob shapes + fur texture   | OxfordPets  |
//! | cars196       |     196 | two-tone boxes, fine pose   | StanfordCars|
//! | cifar10       |      10 | coarse color/shape          | CIFAR10     |
//! | dtd47         |      47 | sinusoidal gratings         | DTD         |
//! | eurosat10     |      10 | field color patches         | EuroSAT     |
//! | fgvc100       |     100 | silhouettes, fine aspect    | FGVC        |
//! | resisc45      |      45 | layout motifs               | RESISC45    |
//! | cifar100      |     100 | color/shape fine            | CIFAR100    |
//!
//! Class identity controls a small number of continuous parameters
//! (frequency, orientation, hue, aspect); fine-grained datasets (cars196,
//! fgvc100) space classes closely so linear probing is weak and adaptation
//! matters — reproducing the paper's LP << LoRA/FourierFT << FF ordering.

use super::ImgExample;
use crate::tensor::rng::Rng;

pub const IMG: usize = 32;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VisionSet {
    Pets37,
    Cars196,
    Cifar10,
    Dtd47,
    Eurosat10,
    Fgvc100,
    Resisc45,
    Cifar100,
}

impl VisionSet {
    pub const ALL: [VisionSet; 8] = [
        VisionSet::Pets37,
        VisionSet::Cars196,
        VisionSet::Cifar10,
        VisionSet::Dtd47,
        VisionSet::Eurosat10,
        VisionSet::Fgvc100,
        VisionSet::Resisc45,
        VisionSet::Cifar100,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            VisionSet::Pets37 => "pets37",
            VisionSet::Cars196 => "cars196",
            VisionSet::Cifar10 => "cifar10",
            VisionSet::Dtd47 => "dtd47",
            VisionSet::Eurosat10 => "eurosat10",
            VisionSet::Fgvc100 => "fgvc100",
            VisionSet::Resisc45 => "resisc45",
            VisionSet::Cifar100 => "cifar100",
        }
    }

    pub fn from_name(s: &str) -> Option<VisionSet> {
        Self::ALL.iter().copied().find(|v| v.name() == s)
    }

    pub fn classes(&self) -> usize {
        match self {
            VisionSet::Pets37 => 37,
            VisionSet::Cars196 => 196,
            VisionSet::Cifar10 => 10,
            VisionSet::Dtd47 => 47,
            VisionSet::Eurosat10 => 10,
            VisionSet::Fgvc100 => 100,
            VisionSet::Resisc45 => 45,
            VisionSet::Cifar100 => 100,
        }
    }

    /// Intra-class noise level (fine-grained sets are noisier relative to
    /// class separation, making them harder — mirrors the paper's accuracy
    /// ordering: cars/fgvc hard, cifar10/eurosat easy).
    fn noise(&self) -> f32 {
        match self {
            VisionSet::Cars196 | VisionSet::Fgvc100 => 0.35,
            VisionSet::Pets37 | VisionSet::Dtd47 | VisionSet::Resisc45 => 0.22,
            VisionSet::Cifar100 => 0.18,
            VisionSet::Cifar10 | VisionSet::Eurosat10 => 0.10,
        }
    }

    pub fn render(&self, class: usize, rng: &mut Rng) -> ImgExample {
        assert!(class < self.classes());
        let c = self.classes() as f32;
        let t = class as f32 / c; // class parameter in [0, 1)
        let noise = self.noise();
        let pixels = match self {
            VisionSet::Dtd47 | VisionSet::Resisc45 => grating(t, noise, rng),
            VisionSet::Cifar10 | VisionSet::Cifar100 | VisionSet::Eurosat10 => {
                color_patch(t, c, noise, rng)
            }
            VisionSet::Pets37 | VisionSet::Fgvc100 => blob(t, noise, rng),
            VisionSet::Cars196 => two_tone_box(t, noise, rng),
        };
        ImgExample { pixels, label: class as i32 }
    }

    pub fn split(&self, split: &str, count: usize, seed: u64) -> Vec<ImgExample> {
        let tag: u64 = match split {
            "train" => 0xA,
            "val" => 0xB,
            "test" => 0xC,
            other => panic!("unknown split {other}"),
        };
        let mut rng = Rng::new(seed ^ 0x515 ^ (self.classes() as u64) << 20).fork(tag);
        (0..count)
            .map(|i| {
                let class = i % self.classes().min(count);
                let class = if count < self.classes() { rng.below(self.classes()) } else { class };
                self.render(class, &mut rng)
            })
            .collect()
    }
}

/// ImageNet-21k-sim: a 200-class mixture across all generator families,
/// used to pretrain the ViT backbones.
pub fn imagenet_sim(count: usize, classes: usize, seed: u64) -> Vec<ImgExample> {
    let mut rng = Rng::new(seed ^ 0x121C);
    (0..count)
        .map(|i| {
            let class = i % classes;
            let t = class as f32 / classes as f32;
            // family by class id: rotate through the three generators
            let pixels = match class % 3 {
                0 => grating(t, 0.15, &mut rng),
                1 => color_patch(t, classes as f32, 0.15, &mut rng),
                _ => blob(t, 0.15, &mut rng),
            };
            ImgExample { pixels, label: class as i32 }
        })
        .collect()
}

fn base_canvas(rng: &mut Rng, level: f32, noise: f32) -> Vec<f32> {
    (0..IMG * IMG * 3).map(|_| (level + noise * rng.normal()).clamp(0.0, 1.0)).collect()
}

/// Sinusoidal grating: class -> (frequency, orientation, hue).
fn grating(t: f32, noise: f32, rng: &mut Rng) -> Vec<f32> {
    let freq = 1.0 + 7.0 * t + 0.1 * rng.normal();
    let angle = std::f32::consts::PI * (t * 7.0).fract() + 0.05 * rng.normal();
    let hue = (t * 3.0).fract();
    let (ca, sa) = (angle.cos(), angle.sin());
    let mut px = vec![0.0f32; IMG * IMG * 3];
    for y in 0..IMG {
        for x in 0..IMG {
            let u = (x as f32 / IMG as f32 - 0.5) * ca + (y as f32 / IMG as f32 - 0.5) * sa;
            let s = 0.5 + 0.5 * (2.0 * std::f32::consts::PI * freq * u).sin();
            let i = (y * IMG + x) * 3;
            px[i] = (s * (1.0 - hue) + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 1] = (s * hue + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 2] = (s * 0.5 + noise * rng.normal()).clamp(0.0, 1.0);
        }
    }
    px
}

/// Color-field patches: class -> (rgb palette, split position).
fn color_patch(t: f32, classes: f32, noise: f32, rng: &mut Rng) -> Vec<f32> {
    let r = (t * 5.0).fract();
    let g = (t * 7.0 + 0.3).fract();
    let b = (t * 11.0 + 0.7).fract();
    let split = (4.0 + t * (IMG as f32 - 8.0)) as usize;
    let fine = classes > 50.0;
    let mut px = base_canvas(rng, 0.5, noise * 0.5);
    for y in 0..IMG {
        for x in 0..IMG {
            let i = (y * IMG + x) * 3;
            let top = y < split;
            let (cr, cg, cb) = if top { (r, g, b) } else { (b, r, g) };
            let w = if fine { 0.7 } else { 1.0 };
            px[i] = (px[i] * (1.0 - w) + cr * w + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 1] = (px[i + 1] * (1.0 - w) + cg * w + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 2] = (px[i + 2] * (1.0 - w) + cb * w + noise * rng.normal()).clamp(0.0, 1.0);
        }
    }
    px
}

/// Centered soft blob: class -> (radius, eccentricity, hue).
fn blob(t: f32, noise: f32, rng: &mut Rng) -> Vec<f32> {
    let radius = 0.15 + 0.3 * (t * 3.0).fract();
    let ecc = 0.5 + (t * 13.0).fract();
    let hue = (t * 5.0 + 0.2).fract();
    let cx = 0.5 + 0.05 * rng.normal();
    let cy = 0.5 + 0.05 * rng.normal();
    let mut px = base_canvas(rng, 0.2, noise * 0.6);
    for y in 0..IMG {
        for x in 0..IMG {
            let dx = (x as f32 / IMG as f32 - cx) / radius;
            let dy = (y as f32 / IMG as f32 - cy) / (radius * ecc);
            let d = dx * dx + dy * dy;
            if d < 1.0 {
                let s = 1.0 - d;
                let i = (y * IMG + x) * 3;
                px[i] = (hue * s + noise * rng.normal()).clamp(0.0, 1.0);
                px[i + 1] = ((1.0 - hue) * s + noise * rng.normal()).clamp(0.0, 1.0);
                px[i + 2] = (0.8 * s + noise * rng.normal()).clamp(0.0, 1.0);
            }
        }
    }
    px
}

/// Two-tone rectangle ("car body + roof"): class -> (aspect, hues, y-pos).
fn two_tone_box(t: f32, noise: f32, rng: &mut Rng) -> Vec<f32> {
    let aspect = 0.3 + 0.5 * (t * 17.0).fract();
    let hue1 = (t * 29.0).fract();
    let hue2 = (t * 31.0 + 0.5).fract();
    let ypos = 8 + ((t * 37.0).fract() * 12.0) as usize;
    let mut px = base_canvas(rng, 0.35, noise * 0.5);
    let w = (IMG as f32 * 0.7) as usize;
    let h = (w as f32 * aspect) as usize;
    let x0 = (IMG - w) / 2;
    for y in ypos..(ypos + h).min(IMG) {
        for x in x0..x0 + w {
            let i = (y * IMG + x) * 3;
            let roof = y < ypos + h / 2;
            let hue = if roof { hue1 } else { hue2 };
            px[i] = (hue + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 1] = (1.0 - hue + noise * rng.normal()).clamp(0.0, 1.0);
            px[i + 2] = (0.5 * hue + 0.25 + noise * rng.normal()).clamp(0.0, 1.0);
        }
    }
    px
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_counts_match_paper_datasets() {
        let want = [37, 196, 10, 47, 10, 100, 45, 100];
        for (v, w) in VisionSet::ALL.iter().zip(want) {
            assert_eq!(v.classes(), w, "{}", v.name());
        }
    }

    #[test]
    fn pixels_are_valid() {
        let mut rng = Rng::new(5);
        for v in VisionSet::ALL {
            let ex = v.render(v.classes() - 1, &mut rng);
            assert_eq!(ex.pixels.len(), IMG * IMG * 3);
            assert!(ex.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn same_class_images_are_more_similar_than_cross_class() {
        // Generator sanity: intra-class L2 < inter-class L2 on average.
        let mut rng = Rng::new(9);
        let v = VisionSet::Cifar10;
        let dist = |a: &ImgExample, b: &ImgExample| -> f32 {
            a.pixels.iter().zip(&b.pixels).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        for _ in 0..20 {
            let a = v.render(3, &mut rng);
            let b = v.render(3, &mut rng);
            let c = v.render(7, &mut rng);
            intra += dist(&a, &b);
            inter += dist(&a, &c);
        }
        assert!(intra < inter, "intra {intra} !< inter {inter}");
    }

    #[test]
    fn fine_grained_sets_are_harder() {
        // Neighboring classes of cars196 are closer than neighboring
        // classes of cifar10 (normalized by intra-class spread).
        let mut rng = Rng::new(4);
        let mut sep = |v: VisionSet| -> f32 {
            let a = v.render(0, &mut rng);
            let b = v.render(1, &mut rng);
            a.pixels.iter().zip(&b.pixels).map(|(x, y)| (x - y).abs()).sum::<f32>()
        };
        let cars = sep(VisionSet::Cars196);
        let cifar = sep(VisionSet::Cifar10);
        assert!(cars < cifar, "cars sep {cars} should be < cifar sep {cifar}");
    }

    #[test]
    fn splits_cover_all_classes() {
        let exs = VisionSet::Cifar10.split("train", 100, 3);
        let mut seen = std::collections::HashSet::new();
        for e in &exs {
            seen.insert(e.label);
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn imagenet_sim_has_all_labels() {
        let exs = imagenet_sim(400, 200, 1);
        let max = exs.iter().map(|e| e.label).max().unwrap();
        assert_eq!(max, 199);
    }
}
