//! Six GLUE-like NLU tasks over the shared grammar (Table 2, Figures 4/5/6).
//!
//! Each task is a genuine sequence-understanding problem (not a bag-of-
//! words shortcut around position 0): labels depend on token interactions
//! (negation scope, cross-sentence overlap, word order), so attention —
//! and therefore the adapted W_q/W_v — matters. Metrics mirror the paper:
//! accuracy for SST/MRPC/QNLI/RTE, Matthews correlation for CoLA, Pearson
//! correlation for STS-B.

use super::vocab::{vocab, Class, CLS, SEP};
use super::{Label, TextExample};
use crate::tensor::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GlueTask {
    Sst2,
    Mrpc,
    Cola,
    Qnli,
    Rte,
    Stsb,
}

impl GlueTask {
    pub const ALL: [GlueTask; 6] =
        [GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Cola, GlueTask::Qnli, GlueTask::Rte, GlueTask::Stsb];

    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Sst2 => "sst2",
            GlueTask::Mrpc => "mrpc",
            GlueTask::Cola => "cola",
            GlueTask::Qnli => "qnli",
            GlueTask::Rte => "rte",
            GlueTask::Stsb => "stsb",
        }
    }

    pub fn from_name(s: &str) -> Option<GlueTask> {
        Self::ALL.iter().copied().find(|t| t.name() == s)
    }

    pub fn metric_name(&self) -> &'static str {
        match self {
            GlueTask::Cola => "mcc",
            GlueTask::Stsb => "pcc",
            _ => "acc",
        }
    }

    pub fn is_regression(&self) -> bool {
        matches!(self, GlueTask::Stsb)
    }

    pub fn num_classes(&self) -> usize {
        2 // all classification tasks here are binary; head is 3-wide (cfg)
    }

    /// Generate one example.
    pub fn example(&self, rng: &mut Rng) -> TextExample {
        match self {
            GlueTask::Sst2 => sst2(rng),
            GlueTask::Mrpc => mrpc(rng),
            GlueTask::Cola => cola(rng),
            GlueTask::Qnli => qnli(rng),
            GlueTask::Rte => rte(rng),
            GlueTask::Stsb => stsb(rng),
        }
    }

    /// Deterministic split: train / val draws from disjoint substreams.
    pub fn split(&self, split: &str, count: usize, seed: u64) -> Vec<TextExample> {
        let tag = match split {
            "train" => 1,
            "val" => 2,
            "test" => 3,
            other => panic!("unknown split {other}"),
        };
        let mut rng = Rng::new(seed ^ (0x6C75 << 16) ^ (self.name().len() as u64) << 8 ^ tag)
            .fork(fxhash(self.name()) ^ tag);
        (0..count).map(|_| self.example(&mut rng)).collect()
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

fn pick(rng: &mut Rng, class: Class) -> i32 {
    let ids = vocab().ids_of(class);
    ids[rng.below(ids.len())]
}

/// "the movie was (not)? very good/bad ..." — label flips under negation.
fn sst2(rng: &mut Rng) -> TextExample {
    let mut toks = vec![CLS];
    let positive = rng.chance(0.5);
    let negated = rng.chance(0.3);
    toks.push(pick(rng, Class::Determiner));
    toks.push(pick(rng, Class::Noun));
    toks.push(pick(rng, Class::Verb));
    if negated {
        toks.push(pick(rng, Class::Negation));
    }
    if rng.chance(0.6) {
        toks.push(pick(rng, Class::Adverb));
    }
    toks.push(pick(rng, if positive { Class::PosAdj } else { Class::NegAdj }));
    // distractor clause with a *neutral* adjective
    if rng.chance(0.5) {
        toks.push(pick(rng, Class::Determiner));
        toks.push(pick(rng, Class::NeutralAdj));
        toks.push(pick(rng, Class::Noun));
    }
    let label = (positive ^ negated) as i32;
    TextExample { tokens: toks, label: Label::Class(label) }
}

fn content_sentence(rng: &mut Rng, len: usize) -> Vec<i32> {
    let mut s = Vec::with_capacity(len);
    s.push(pick(rng, Class::Determiner));
    s.push(pick(rng, Class::NeutralAdj));
    s.push(pick(rng, Class::Noun));
    s.push(pick(rng, Class::Verb));
    while s.len() < len {
        s.push(pick(rng, Class::Noun));
    }
    s
}

/// Paraphrase: same content words (shuffled interior) vs different content.
fn mrpc(rng: &mut Rng) -> TextExample {
    let s1 = content_sentence(rng, 6);
    let paraphrase = rng.chance(0.5);
    let s2 = if paraphrase {
        let mut s2 = s1.clone();
        // shuffle the non-initial tokens (word-order change, same content)
        let tail = &mut s2[1..];
        rng.shuffle(tail);
        s2
    } else {
        // change the content nouns
        let mut s2 = content_sentence(rng, 6);
        s2[2] = pick(rng, Class::Noun);
        s2
    };
    let mut toks = vec![CLS];
    toks.extend(&s1);
    toks.push(SEP);
    toks.extend(&s2);
    TextExample { tokens: toks, label: Label::Class(paraphrase as i32) }
}

/// Acceptability: canonical order DET (ADV)? ADJ NOUN VERB vs a corrupted
/// permutation of the same words.
fn cola(rng: &mut Rng) -> TextExample {
    let mut s = vec![
        pick(rng, Class::Determiner),
        pick(rng, Class::Adverb),
        pick(rng, Class::NeutralAdj),
        pick(rng, Class::Noun),
        pick(rng, Class::Verb),
        pick(rng, Class::PosAdj),
    ];
    let acceptable = rng.chance(0.5);
    if !acceptable {
        // corrupt: swap two distinct word-class positions
        let i = rng.below(s.len());
        let mut j = rng.below(s.len());
        while j == i {
            j = rng.below(s.len());
        }
        s.swap(i, j);
        // tiny chance the swap is a no-op class-wise; force a det/verb swap
        s.swap(0, 4);
    }
    let mut toks = vec![CLS];
    toks.extend(s);
    TextExample { tokens: toks, label: Label::Class(acceptable as i32) }
}

/// QNLI-like: "what/where NOUN" question + sentence; entailed iff the
/// sentence mentions the queried noun.
fn qnli(rng: &mut Rng) -> TextExample {
    let noun = pick(rng, Class::Noun);
    let entailed = rng.chance(0.5);
    let mut toks = vec![CLS, pick(rng, Class::Question), noun, SEP];
    let mut sent = content_sentence(rng, 7);
    if entailed {
        let pos = 2 + rng.below(4);
        sent[pos] = noun;
    } else {
        // ensure the noun does not appear
        for t in sent.iter_mut() {
            if *t == noun {
                *t = pick(rng, Class::Noun);
            }
        }
        if sent.contains(&noun) {
            sent[2] = noun + 1; // fallback; ids are dense within class
        }
    }
    toks.extend(sent);
    TextExample { tokens: toks, label: Label::Class(entailed as i32) }
}

/// RTE-like: hypothesis content ⊆ premise content => entailment.
fn rte(rng: &mut Rng) -> TextExample {
    let premise = content_sentence(rng, 8);
    let entailed = rng.chance(0.5);
    let mut hypo: Vec<i32> = premise[..4].to_vec();
    if !entailed {
        // introduce a novel content word
        hypo[2] = pick(rng, Class::Noun);
        if premise.contains(&hypo[2]) {
            hypo[2] = pick(rng, Class::Verb);
        }
    }
    let mut toks = vec![CLS];
    toks.extend(&premise);
    toks.push(SEP);
    toks.extend(&hypo);
    TextExample { tokens: toks, label: Label::Class(entailed as i32) }
}

/// STS-B-like: similarity in [0, 5] = 5 x token-overlap of two sentences.
fn stsb(rng: &mut Rng) -> TextExample {
    let s1 = content_sentence(rng, 6);
    let overlap = rng.below(7) as f32 / 6.0; // target similarity fraction
    let keep = (overlap * 6.0).round() as usize;
    let mut s2 = s1.clone();
    for i in keep..6 {
        s2[i] = pick(rng, Class::Noun);
    }
    // recompute actual overlap (replacement may coincide)
    let same = s1.iter().zip(&s2).filter(|(a, b)| a == b).count();
    let score = 5.0 * same as f32 / 6.0;
    let mut toks = vec![CLS];
    toks.extend(&s1);
    toks.push(SEP);
    toks.extend(&s2);
    TextExample { tokens: toks, label: Label::Score(score) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_are_deterministic_and_disjoint_streams() {
        let a = GlueTask::Rte.split("train", 50, 7);
        let b = GlueTask::Rte.split("train", 50, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
        }
        let c = GlueTask::Rte.split("val", 50, 7);
        let overlap = a.iter().filter(|e| c.iter().any(|f| f.tokens == e.tokens)).count();
        assert!(overlap < 5, "train/val overlap {overlap}");
    }

    #[test]
    fn labels_are_balancedish() {
        for task in [GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Cola, GlueTask::Qnli, GlueTask::Rte] {
            let exs = task.split("train", 400, 3);
            let pos = exs
                .iter()
                .filter(|e| matches!(e.label, Label::Class(1)))
                .count();
            assert!((100..300).contains(&pos), "{}: {pos}/400 positive", task.name());
        }
    }

    #[test]
    fn sst2_label_consistent_with_tokens() {
        // Reconstruct the rule: polarity xor negation.
        let v = vocab();
        for ex in GlueTask::Sst2.split("train", 200, 11) {
            let has_neg = ex.tokens.iter().any(|&t| v.class_of(t) == Some(Class::Negation));
            let has_pos = ex.tokens.iter().any(|&t| v.class_of(t) == Some(Class::PosAdj));
            let want = (has_pos ^ has_neg) as i32;
            match ex.label {
                Label::Class(c) => assert_eq!(c, want),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn qnli_entailment_matches_mention() {
        for ex in GlueTask::Qnli.split("train", 200, 5) {
            let noun = ex.tokens[2];
            let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            let mentioned = ex.tokens[sep + 1..].contains(&noun);
            match ex.label {
                Label::Class(c) => assert_eq!(c == 1, mentioned),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn stsb_scores_in_range_and_varied() {
        let exs = GlueTask::Stsb.split("train", 300, 9);
        let mut lo = f32::MAX;
        let mut hi = f32::MIN;
        for e in &exs {
            if let Label::Score(s) = e.label {
                assert!((0.0..=5.0).contains(&s));
                lo = lo.min(s);
                hi = hi.max(s);
            }
        }
        assert!(lo < 2.0 && hi > 4.0, "score range [{lo}, {hi}] too narrow");
    }

    #[test]
    fn sequences_fit_encoder_window() {
        for t in GlueTask::ALL {
            for e in t.split("train", 100, 1) {
                assert!(e.tokens.len() <= 32, "{} len {}", t.name(), e.tokens.len());
            }
        }
    }
}
