//! Instruction-tuning corpus + evaluation questions (Table 4, Figure 1).
//!
//! Alpaca-style: each example is (instruction-op, input tokens) -> output
//! tokens, with the loss masked to the response. The operations are exact
//! sequence-manipulation tasks so the MT-Bench-sim "judge" (metrics::judge)
//! can score responses deterministically — our stand-in for GPT-4 scoring:
//! a response earns up to 10 points for exact-match, with partial credit
//! per correct token, mirroring how the paper reports mean judge scores.

use super::vocab::{vocab, Class, BOS, EOS, SEP};
use super::{Label, TextExample};
use crate::tensor::rng::Rng;

/// The instruction operations (the "skills" fine-tuning must teach).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Reverse,
    Sort,
    Copy,
    First,
    Last,
    Repeat,
    Unique,
    Count,
}

impl Op {
    pub const ALL: [Op; 8] =
        [Op::Reverse, Op::Sort, Op::Copy, Op::First, Op::Last, Op::Repeat, Op::Unique, Op::Count];

    fn word(&self) -> &'static str {
        match self {
            Op::Reverse => "reverse",
            Op::Sort => "sort",
            Op::Copy => "copy",
            Op::First => "first",
            Op::Last => "last",
            Op::Repeat => "repeat",
            Op::Unique => "unique",
            Op::Count => "count",
        }
    }

    pub fn token(&self) -> i32 {
        let v = vocab();
        v.ids_of(Class::Op)
            .into_iter()
            .find(|&id| v.word(id) == self.word())
            .expect("op word in vocab")
    }

    /// Ground-truth output for an input over number tokens.
    pub fn apply(&self, input: &[i32]) -> Vec<i32> {
        match self {
            Op::Reverse => input.iter().rev().copied().collect(),
            Op::Sort => {
                let mut s = input.to_vec();
                s.sort_unstable();
                s
            }
            Op::Copy => input.to_vec(),
            Op::First => vec![input[0]],
            Op::Last => vec![*input.last().unwrap()],
            Op::Repeat => {
                let mut out = input.to_vec();
                out.extend(input);
                out
            }
            Op::Unique => {
                let mut out = Vec::new();
                for &t in input {
                    if !out.contains(&t) {
                        out.push(t);
                    }
                }
                out
            }
            Op::Count => {
                let v = vocab();
                let nums = v.ids_of(Class::Number);
                vec![nums[input.len().min(nums.len() - 1)]]
            }
        }
    }
}

/// One instruction prompt: BOS op x1..xk SEP (answer) EOS.
#[derive(Debug, Clone)]
pub struct Question {
    pub op: Op,
    pub input: Vec<i32>,
}

impl Question {
    pub fn sample(rng: &mut Rng, ops: &[Op]) -> Question {
        let v = vocab();
        let nums = v.ids_of(Class::Number);
        let k = 3 + rng.below(4); // 3..6 number tokens
        let input: Vec<i32> = (0..k).map(|_| nums[rng.below(nums.len())]).collect();
        Question { op: *rng.pick(ops), input }
    }

    pub fn prompt(&self) -> Vec<i32> {
        let mut p = vec![BOS, self.op.token()];
        p.extend(&self.input);
        p.push(SEP);
        p
    }

    pub fn answer(&self) -> Vec<i32> {
        let mut a = self.op.apply(&self.input);
        a.push(EOS);
        a
    }

    /// LM training example with response-only loss mask.
    pub fn example(&self, seqlen: usize) -> TextExample {
        let mut tokens = self.prompt();
        let prompt_len = tokens.len();
        tokens.extend(self.answer());
        let mut y = tokens[1..].to_vec();
        y.push(0);
        let mut mask = vec![0.0f32; tokens.len()];
        for m in mask.iter_mut().take(tokens.len() - 1).skip(prompt_len - 1) {
            *m = 1.0;
        }
        tokens.truncate(seqlen);
        y.truncate(seqlen);
        mask.truncate(seqlen);
        TextExample { tokens, label: Label::Seq { target: y, mask } }
    }
}

/// Training corpus (all ops mixed — "Alpaca-sim").
pub fn train_set(count: usize, seqlen: usize, seed: u64) -> Vec<TextExample> {
    let mut rng = Rng::new(seed ^ 0xA17ACA);
    (0..count).map(|_| Question::sample(&mut rng, &Op::ALL).example(seqlen)).collect()
}

/// MT-Bench-sim: held-out questions over ALL ops (broad skill coverage).
pub fn mt_bench_sim(count: usize, seed: u64) -> Vec<Question> {
    let mut rng = Rng::new(seed ^ 0x177B);
    (0..count).map(|_| Question::sample(&mut rng, &Op::ALL)).collect()
}

/// Vicuna-sim: the easier subset (copy/first/last/reverse), like Vicuna
/// Eval's shorter free-form questions.
pub fn vicuna_sim(count: usize, seed: u64) -> Vec<Question> {
    let ops = [Op::Copy, Op::First, Op::Last, Op::Reverse];
    let mut rng = Rng::new(seed ^ 0x71C);
    (0..count).map(|_| Question::sample(&mut rng, &ops)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_compute_correct_answers() {
        let v = vocab();
        let nums = v.ids_of(Class::Number);
        let input = vec![nums[3], nums[1], nums[3], nums[0]];
        assert_eq!(Op::Reverse.apply(&input), vec![nums[0], nums[3], nums[1], nums[3]]);
        assert_eq!(Op::Sort.apply(&input), {
            let mut s = input.clone();
            s.sort_unstable();
            s
        });
        assert_eq!(Op::First.apply(&input), vec![nums[3]]);
        assert_eq!(Op::Unique.apply(&input), vec![nums[3], nums[1], nums[0]]);
        assert_eq!(Op::Count.apply(&input), vec![nums[4]]);
    }

    #[test]
    fn example_mask_is_response_only() {
        let mut rng = Rng::new(1);
        let q = Question::sample(&mut rng, &Op::ALL);
        let ex = q.example(48);
        if let Label::Seq { mask, .. } = &ex.label {
            let masked: usize = mask.iter().map(|&m| m as usize).sum();
            assert_eq!(masked, q.answer().len());
        } else {
            panic!();
        }
    }

    #[test]
    fn benches_are_deterministic() {
        let a = mt_bench_sim(10, 3);
        let b = mt_bench_sim(10, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.input, y.input);
            assert_eq!(x.op, y.op);
        }
    }

    #[test]
    fn vicuna_uses_easy_ops_only() {
        for q in vicuna_sim(50, 7) {
            assert!(matches!(q.op, Op::Copy | Op::First | Op::Last | Op::Reverse));
        }
    }

    #[test]
    fn fits_decoder_window() {
        for ex in train_set(100, 48, 5) {
            assert!(ex.tokens.len() <= 48);
        }
    }
}
