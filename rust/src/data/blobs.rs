//! Figure 7 dataset: 8 Gaussian blobs in 2D.
//!
//! Paper appendix C.2: "we specify a 2D center point for each class of data
//! in the 8 classes, and randomly add Gaussian noise based on that point".
//! Centers sit on a circle; the task is trained with a single 64x64 hidden
//! layer adapted by LoRA (r=1) vs FourierFT (n=128) at equal trainable
//! parameter counts.

use crate::tensor::rng::Rng;

pub const CLASSES: usize = 8;

#[derive(Debug, Clone, Copy)]
pub struct Point {
    pub x: f32,
    pub y: f32,
    pub class: usize,
}

/// Class centers on a radius-2 circle.
pub fn center(class: usize) -> (f32, f32) {
    let ang = 2.0 * std::f32::consts::PI * class as f32 / CLASSES as f32;
    (2.0 * ang.cos(), 2.0 * ang.sin())
}

pub fn sample(rng: &mut Rng, noise: f32) -> Point {
    let class = rng.below(CLASSES);
    let (cx, cy) = center(class);
    Point { x: cx + noise * rng.normal(), y: cy + noise * rng.normal(), class }
}

pub fn dataset(count: usize, noise: f32, seed: u64) -> Vec<Point> {
    let mut rng = Rng::new(seed ^ 0xB10B);
    (0..count).map(|_| sample(&mut rng, noise)).collect()
}

/// Collate into a step batch for the `mlp` artifacts.
pub fn collate(points: &[Point]) -> std::collections::HashMap<String, crate::tensor::Tensor> {
    let b = points.len();
    let mut x = Vec::with_capacity(b * 2);
    let mut y = Vec::with_capacity(b);
    for p in points {
        x.push(p.x);
        x.push(p.y);
        y.push(p.class as i32);
    }
    std::collections::HashMap::from([
        ("x".to_string(), crate::tensor::Tensor::f32(&[b, 2], x)),
        ("y".to_string(), crate::tensor::Tensor::i32(&[b], y)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centers_are_distinct_and_on_circle() {
        for c in 0..CLASSES {
            let (x, y) = center(c);
            assert!((x * x + y * y - 4.0).abs() < 1e-5);
        }
        assert_ne!(center(0), center(1));
    }

    #[test]
    fn low_noise_points_are_classifiable_by_nearest_center() {
        let pts = dataset(500, 0.3, 1);
        let correct = pts
            .iter()
            .filter(|p| {
                let mut best = (0, f32::MAX);
                for c in 0..CLASSES {
                    let (cx, cy) = center(c);
                    let d = (p.x - cx).powi(2) + (p.y - cy).powi(2);
                    if d < best.1 {
                        best = (c, d);
                    }
                }
                best.0 == p.class
            })
            .count();
        assert!(correct > 480, "{correct}/500 nearest-center correct");
    }

    #[test]
    fn all_classes_present() {
        let pts = dataset(200, 0.3, 2);
        let mut seen = [false; CLASSES];
        for p in &pts {
            seen[p.class] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
