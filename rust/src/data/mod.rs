//! Synthetic data substrate.
//!
//! The paper's experiments need GLUE, E2E, Alpaca, and 8 vision datasets
//! plus pretrained base models — none available in this offline sandbox
//! (repro band 0/5). Per DESIGN.md §2 every workload is re-created as a
//! *procedural* dataset that exercises the identical code path:
//!
//! * [`vocab`] — a 1000-token vocabulary with word classes (the grammar's
//!   terminals) shared by every text task and the rust-side detokenizer.
//! * [`glue`] — six GLUE-like NLU tasks (sentiment, paraphrase,
//!   acceptability, QNLI-, RTE-, STS-B-like) built from a rule grammar.
//! * [`e2e`] — restaurant slot-table -> utterance generation (E2E NLG).
//! * [`instruct`] — instruction-following tasks + the deterministic judge
//!   questions (MT-Bench-sim / Vicuna-sim).
//! * [`vision`] — eight procedural image datasets mirroring the paper's
//!   class counts and difficulty ordering, plus an ImageNet-21k-sim
//!   pretraining mixture.
//! * [`blobs`] — the Figure 7 two-dimensional 8-class Gaussian dataset.
//! * [`corpus`] — broad pretraining streams (masked-token for encoders,
//!   next-token for decoders).
//!
//! Everything is seeded and deterministic; splits never overlap by
//! construction (disjoint index ranges of one generator stream).

pub mod blobs;
pub mod corpus;
pub mod e2e;
pub mod glue;
pub mod instruct;
pub mod vision;
pub mod vocab;

use crate::tensor::Tensor;
use std::collections::HashMap;

/// A text example: token ids plus a task label.
#[derive(Debug, Clone)]
pub struct TextExample {
    pub tokens: Vec<i32>,
    pub label: Label,
}

#[derive(Debug, Clone)]
pub enum Label {
    Class(i32),
    Score(f32),
    /// Target token sequence (NLG); paired with a loss mask over positions.
    Seq { target: Vec<i32>, mask: Vec<f32> },
}

/// An image example: HWC f32 pixels in [0, 1] plus a class id.
#[derive(Debug, Clone)]
pub struct ImgExample {
    pub pixels: Vec<f32>, // img*img*3
    pub label: i32,
}

/// Pad / truncate a token sequence to `len` (PAD = 0).
pub fn pad_to(tokens: &[i32], len: usize) -> Vec<i32> {
    let mut out = tokens.to_vec();
    out.truncate(len);
    out.resize(len, vocab::PAD);
    out
}

/// Collate classification text examples into a step batch.
pub fn collate_text(examples: &[TextExample], seqlen: usize) -> HashMap<String, Tensor> {
    let b = examples.len();
    let mut x = Vec::with_capacity(b * seqlen);
    let mut y_cls = Vec::with_capacity(b);
    let mut y_score = Vec::with_capacity(b);
    let mut is_score = false;
    for ex in examples {
        x.extend(pad_to(&ex.tokens, seqlen));
        match &ex.label {
            Label::Class(c) => y_cls.push(*c),
            Label::Score(s) => {
                is_score = true;
                y_score.push(*s);
            }
            Label::Seq { .. } => panic!("use collate_lm for seq labels"),
        }
    }
    let mut out = HashMap::from([("x".to_string(), Tensor::i32(&[b, seqlen], x))]);
    if is_score {
        out.insert("y".to_string(), Tensor::f32(&[b], y_score));
    } else {
        out.insert("y".to_string(), Tensor::i32(&[b], y_cls));
    }
    out
}

/// Collate LM examples: x = tokens, y = next-token targets, mask = loss mask.
pub fn collate_lm(examples: &[TextExample], seqlen: usize) -> HashMap<String, Tensor> {
    let b = examples.len();
    let mut x = Vec::with_capacity(b * seqlen);
    let mut y = Vec::with_capacity(b * seqlen);
    let mut m = Vec::with_capacity(b * seqlen);
    for ex in examples {
        let toks = pad_to(&ex.tokens, seqlen);
        match &ex.label {
            Label::Seq { target, mask } => {
                x.extend(&toks);
                y.extend(pad_to(target, seqlen));
                let mut mm = mask.clone();
                mm.truncate(seqlen);
                mm.resize(seqlen, 0.0);
                m.extend(mm);
            }
            _ => panic!("collate_lm wants Seq labels"),
        }
    }
    HashMap::from([
        ("x".to_string(), Tensor::i32(&[b, seqlen], x)),
        ("y".to_string(), Tensor::i32(&[b, seqlen], y)),
        ("mask".to_string(), Tensor::f32(&[b, seqlen], m)),
    ])
}

/// Collate image examples.
pub fn collate_img(examples: &[ImgExample], img: usize) -> HashMap<String, Tensor> {
    let b = examples.len();
    let mut x = Vec::with_capacity(b * img * img * 3);
    let mut y = Vec::with_capacity(b);
    for ex in examples {
        assert_eq!(ex.pixels.len(), img * img * 3);
        x.extend(&ex.pixels);
        y.push(ex.label);
    }
    HashMap::from([
        ("x".to_string(), Tensor::f32(&[b, img, img, 3], x)),
        ("y".to_string(), Tensor::i32(&[b], y)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_truncates_and_pads() {
        assert_eq!(pad_to(&[1, 2, 3], 2), vec![1, 2]);
        assert_eq!(pad_to(&[1], 3), vec![1, vocab::PAD, vocab::PAD]);
    }

    #[test]
    fn collate_text_shapes() {
        let exs = vec![
            TextExample { tokens: vec![1, 2], label: Label::Class(1) },
            TextExample { tokens: vec![3], label: Label::Class(0) },
        ];
        let b = collate_text(&exs, 4);
        assert_eq!(b["x"].shape, vec![2, 4]);
        assert_eq!(b["y"].shape, vec![2]);
        assert_eq!(b["y"].dtype(), "i32");
    }

    #[test]
    fn collate_regression_emits_f32_labels() {
        let exs = vec![TextExample { tokens: vec![1], label: Label::Score(2.5) }];
        let b = collate_text(&exs, 4);
        assert_eq!(b["y"].dtype(), "f32");
    }

    #[test]
    fn collate_lm_shapes_and_mask() {
        let exs = vec![TextExample {
            tokens: vec![4, 10, 11],
            label: Label::Seq { target: vec![10, 11, 5], mask: vec![0.0, 1.0, 1.0] },
        }];
        let b = collate_lm(&exs, 5);
        assert_eq!(b["x"].shape, vec![1, 5]);
        assert_eq!(b["mask"].as_f32().unwrap(), &[0.0, 1.0, 1.0, 0.0, 0.0]);
    }
}
