//! # fourier-peft
//!
//! Production-grade reproduction of **"Parameter-Efficient Fine-Tuning with
//! Discrete Fourier Transform"** (FourierFT, ICML 2024) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernel computing
//!   ΔW = α·Re(IDFT2(ToDense(E, c))) as a rank-n trig matmul (MXU-form).
//! * **L2** (`python/compile/`) — JAX models (MLP / encoder / decoder / ViT)
//!   with pluggable PEFT methods, fused Adam train/eval steps, AOT-lowered
//!   to HLO text artifacts.
//! * **L3** (this crate) — the coordinator: the engine-split runtime
//!   (PJRT *or* pure host), synthetic data generators, metrics, the
//!   adapter store/serving layer, experiment drivers for every table and
//!   figure in the paper, and benches.
//!
//! Python never runs at train/serve time; `make artifacts` is the only
//! python invocation — and with the default **host engine** it is not
//! needed at all.
//!
//! ## Step engines
//!
//! Training and serving dispatch through the backend-neutral
//! [`runtime::StepEngine`] trait (`init_state / step / eval /
//! adapt_tensors / set_adapt` over a host-tensor
//! [`runtime::ParamSet`]). [`runtime::HostEngine`] is a pure-Rust
//! forward + analytic-backward implementation over the sim model zoo
//! ([`runtime::host::zoo`]) with method gradients from each
//! [`adapter::method::DeltaMethod`]'s `site_delta_grad` adjoint — the
//! FourierFT backward is the transpose of the cached
//! [`fourier::ReconstructPlan`] GEMM. [`runtime::XlaEngine`] wraps the
//! compiled-HLO [`runtime::Executable`]. Select with
//! `repro … --engine {host,xla}`; host is the default, so the default
//! build trains every experiment offline.
//!
//! ## Reconstruction plan cache
//!
//! The host-side ΔW hot path is GEMM-formulated: [`fourier::plan::ReconstructPlan`]
//! factors the rank-n trig expansion into one (d1 × 2n)·(2n × d2) product
//! executed by the multi-threaded blocked kernel in [`tensor::par`], with
//! twiddle tables built once per (d1, d2, entries) and shared process-wide
//! through [`fourier::plan::global`]. The serving layer
//! ([`coordinator::serving`]) stacks per-adapter caches on top (decode LRU
//! in [`adapter::AdapterStore`], tensor/ΔW sets in
//! [`coordinator::serving::SwapCache`]) so a warm adapter swap is a pair of
//! hash lookups — no disk read, no decode, no inverse DFT.
//!
//! ## Adapter-method registry
//!
//! ΔW-producing PEFT methods are pluggable: [`adapter::method`] defines
//! the [`adapter::method::DeltaMethod`] trait and a process-wide registry
//! (`get` / `register` / `ids`) that the merge path, both serving cache
//! layers, the scheduler's `DeltaRunner`, budget arithmetic, and the CLI
//! all dispatch through. Built-ins: `fourierft`, `lora`, `dense`,
//! `bitfit`, `loca` (learned-location cosine components), `circulant`
//! (circulant×diagonal). Adapter files (format v2, [`adapter::format`])
//! are self-describing — method id, per-tensor (site, role), per-site
//! dims — with a v1 read-compat shim. See the module docs for how to add
//! a method.
//!
//! ## Serving scheduler
//!
//! Queues are served by the concurrent micro-batching scheduler in
//! [`coordinator::scheduler`]: bounded admission, adapter-affinity
//! coalescing (deterministic, admission-tick-driven), and a scoped worker
//! pool sharing the cache stack through lock-partitioned shards
//! ([`adapter::SharedAdapterStore`], [`coordinator::serving::SharedSwap`]).
//! Worker threads are claimed from the matmul budget
//! ([`tensor::par::reserve_threads`]) so nested GEMMs never oversubscribe
//! the machine. Reproducible workloads (Zipf adapter popularity,
//! configurable arrival order) live in [`coordinator::workload`].
//!
//! ## Cluster simulation
//!
//! [`cluster`] scales the serving stack out to N simulated nodes in one
//! process — consistent-hash placement with virtual nodes and hot-replica
//! promotion ([`cluster::placement`]), deterministic admission-side
//! routing ([`cluster::router`]), two-phase version-fenced publish
//! propagation ([`cluster::fence`]), and seeded failure / rebalance
//! scenarios ([`cluster::sim`]). Responses are bitwise-invariant to node
//! count, replication, and failure schedule; see `repro cluster`.
//!
//! ## Feature flags
//!
//! * `xla-runtime` — use the real `xla` crate (PJRT) for compiled HLO
//!   artifacts. Off by default: the pure-Rust stand-in
//!   (`runtime::xla_compat`) keeps everything except HLO execution fully
//!   functional offline.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results
//! (§Perf has the trig / FFT / GEMM crossover and swap-cost tables).

pub mod adapter;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod fourier;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Default artifacts directory relative to the repo root.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FOURIER_PEFT_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("artifacts"))
}

/// Default runs directory (pretrained bases, adapters, reports).
pub fn runs_dir() -> std::path::PathBuf {
    std::env::var("FOURIER_PEFT_RUNS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| repo_root().join("runs"))
}

/// Locate the repo root: walk up from CWD until a `Cargo.toml` with our
/// package name is found; fall back to CWD.
pub fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let c = dir.join("Cargo.toml");
        if c.exists() {
            if let Ok(text) = std::fs::read_to_string(&c) {
                if text.contains("name = \"fourier_peft\"") || text.contains("name = \"fourier-peft\"") {
                    return dir;
                }
            }
        }
        if !dir.pop() {
            return std::env::current_dir().unwrap_or_else(|_| ".".into());
        }
    }
}
