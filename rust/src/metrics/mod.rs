//! Evaluation metrics — every metric the paper reports, implemented from
//! scratch:
//!
//! * [`classify`] — accuracy, F1, Matthews correlation (CoLA), and re-
//!   exports of Pearson/Spearman (STS-B) from `tensor::linalg`.
//! * [`nlg`] — BLEU, NIST, METEOR, ROUGE-L, CIDEr over token sequences
//!   with multiple references (Table 3 / E2E).
//! * [`judge`] — the deterministic MT-Bench-sim judge (GPT-4 stand-in):
//!   0-10 scores per response (Table 4).
//! * [`fid`] — Fréchet Inception Distance with a fixed random-projection
//!   feature extractor (Table 13 / DreamBooth-sim).

pub mod classify;
pub mod fid;
pub mod judge;
pub mod nlg;

pub use classify::{accuracy, f1_binary, matthews};
pub use nlg::NlgScores;
