//! NLG metrics over token sequences with multiple references — the five
//! E2E-challenge metrics of Table 3: BLEU, NIST, METEOR, ROUGE-L, CIDEr.
//!
//! Implementations follow the canonical definitions:
//! * BLEU-4: corpus-level, geometric mean of clipped n-gram precisions
//!   with brevity penalty (Papineni et al. 2002).
//! * NIST-5: information-weighted n-gram precision with the NIST brevity
//!   factor (Doddington 2002); n-gram information from reference stats.
//! * METEOR: unigram harmonic mean F(alpha=0.9) with a fragmentation
//!   penalty (Banerjee & Lavie 2005), exact matching (token ids have no
//!   stem/synonym structure).
//! * ROUGE-L: LCS-based F-measure (Lin 2004, beta -> recall-weighted).
//! * CIDEr: TF-IDF weighted n-gram cosine, averaged over n=1..4, consensus
//!   across references (Vedantam et al. 2015).

use std::collections::HashMap;

type Gram = Vec<i32>;

fn ngrams(seq: &[i32], n: usize) -> HashMap<Gram, usize> {
    let mut out: HashMap<Gram, usize> = HashMap::new();
    if seq.len() >= n {
        for w in seq.windows(n) {
            *out.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    out
}

/// Corpus-level BLEU-4 (scaled 0-100 like the paper reports).
pub fn bleu(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> f64 {
    assert_eq!(hyps.len(), refs.len());
    let max_n = 4;
    let mut clipped = vec![0usize; max_n];
    let mut total = vec![0usize; max_n];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (h, rs) in hyps.iter().zip(refs) {
        hyp_len += h.len();
        // closest reference length
        ref_len += rs
            .iter()
            .map(|r| r.len())
            .min_by_key(|&l| (l as i64 - h.len() as i64).abs())
            .unwrap_or(0);
        for n in 1..=max_n {
            let hg = ngrams(h, n);
            let mut best: HashMap<Gram, usize> = HashMap::new();
            for r in rs {
                for (g, c) in ngrams(r, n) {
                    let e = best.entry(g).or_insert(0);
                    *e = (*e).max(c);
                }
            }
            for (g, c) in &hg {
                total[n - 1] += c;
                clipped[n - 1] += best.get(g).map(|&m| m.min(*c)).unwrap_or(0);
            }
        }
    }
    let mut log_p = 0.0;
    for n in 0..max_n {
        if total[n] == 0 || clipped[n] == 0 {
            return 0.0;
        }
        log_p += (clipped[n] as f64 / total[n] as f64).ln();
    }
    let bp = if hyp_len >= ref_len {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len.max(1) as f64).exp()
    };
    100.0 * bp * (log_p / max_n as f64).exp()
}

/// NIST-5 (typical magnitude 0-10).
pub fn nist(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> f64 {
    let max_n = 5;
    // n-gram information weights from the reference corpus
    let mut counts: Vec<HashMap<Gram, usize>> = vec![HashMap::new(); max_n + 1];
    let mut total_unigrams = 0usize;
    for rs in refs {
        for r in rs {
            total_unigrams += r.len();
            for n in 1..=max_n {
                for (g, c) in ngrams(r, n) {
                    *counts[n].entry(g).or_insert(0) += c;
                }
            }
        }
    }
    let info = |g: &Gram| -> f64 {
        let n = g.len();
        let c_full = *counts[n].get(g).unwrap_or(&0);
        if c_full == 0 {
            return 0.0;
        }
        let c_parent = if n == 1 {
            total_unigrams
        } else {
            *counts[n - 1].get(&g[..n - 1].to_vec()).unwrap_or(&1)
        };
        ((c_parent as f64) / (c_full as f64)).log2()
    };
    let mut num = vec![0.0f64; max_n];
    let mut den = vec![0usize; max_n];
    let mut hyp_len = 0usize;
    let mut ref_len_avg = 0.0f64;
    for (h, rs) in hyps.iter().zip(refs) {
        hyp_len += h.len();
        ref_len_avg += rs.iter().map(|r| r.len()).sum::<usize>() as f64 / rs.len().max(1) as f64;
        for n in 1..=max_n {
            let hg = ngrams(h, n);
            let mut matched: HashMap<Gram, usize> = HashMap::new();
            for r in rs {
                for (g, c) in ngrams(r, n) {
                    let e = matched.entry(g).or_insert(0);
                    *e = (*e).max(c);
                }
            }
            for (g, c) in &hg {
                den[n - 1] += c;
                let m = matched.get(g).map(|&m| m.min(*c)).unwrap_or(0);
                num[n - 1] += m as f64 * info(g);
            }
        }
    }
    let mut score = 0.0;
    for n in 0..max_n {
        if den[n] > 0 {
            score += num[n] / den[n] as f64;
        }
    }
    // NIST brevity factor
    let beta = (0.5f64).ln() / (1.5f64).ln().powi(2);
    let ratio = hyp_len as f64 / ref_len_avg.max(1.0);
    let bp = (beta * (ratio.min(1.0)).ln().powi(2)).exp();
    score * bp
}

/// METEOR (exact-match variant), 0-100 scale.
pub fn meteor(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> f64 {
    let mut total = 0.0;
    for (h, rs) in hyps.iter().zip(refs) {
        let mut best = 0.0f64;
        for r in rs {
            best = best.max(meteor_single(h, r));
        }
        total += best;
    }
    100.0 * total / hyps.len().max(1) as f64
}

fn meteor_single(h: &[i32], r: &[i32]) -> f64 {
    // greedy left-to-right alignment of exact matches
    let mut used = vec![false; r.len()];
    let mut align: Vec<usize> = Vec::new(); // ref index per matched hyp token
    let mut matches = 0usize;
    for &t in h {
        if let Some(j) = r.iter().enumerate().position(|(j, &rt)| rt == t && !used[j]) {
            used[j] = true;
            align.push(j);
            matches += 1;
        }
    }
    if matches == 0 {
        return 0.0;
    }
    let p = matches as f64 / h.len() as f64;
    let rc = matches as f64 / r.len() as f64;
    let f_mean = p * rc / (0.9 * p + 0.1 * rc);
    // chunks: maximal runs of consecutive alignments
    let mut chunks = 1usize;
    for w in align.windows(2) {
        if w[1] != w[0] + 1 {
            chunks += 1;
        }
    }
    let penalty = 0.5 * (chunks as f64 / matches as f64).powi(3);
    f_mean * (1.0 - penalty)
}

/// ROUGE-L F-measure (0-100).
pub fn rouge_l(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> f64 {
    let mut total = 0.0;
    for (h, rs) in hyps.iter().zip(refs) {
        let mut best = 0.0f64;
        for r in rs {
            let l = lcs(h, r) as f64;
            if l == 0.0 {
                continue;
            }
            let p = l / h.len() as f64;
            let rc = l / r.len() as f64;
            let beta2 = 1.44; // beta = 1.2, per the E2E evaluation script
            best = best.max((1.0 + beta2) * p * rc / (rc + beta2 * p));
        }
        total += best;
    }
    100.0 * total / hyps.len().max(1) as f64
}

fn lcs(a: &[i32], b: &[i32]) -> usize {
    let mut dp = vec![0usize; b.len() + 1];
    for &x in a {
        let mut prev = 0;
        for (j, &y) in b.iter().enumerate() {
            let cur = dp[j + 1];
            dp[j + 1] = if x == y { prev + 1 } else { dp[j + 1].max(dp[j]) };
            prev = cur;
        }
    }
    dp[b.len()]
}

/// CIDEr (typical 0-10 scale as in the paper's Table 3).
pub fn cider(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> f64 {
    let max_n = 4;
    // document frequency over reference sets
    let mut df: Vec<HashMap<Gram, f64>> = vec![HashMap::new(); max_n + 1];
    for rs in refs {
        for n in 1..=max_n {
            let mut seen: HashMap<Gram, bool> = HashMap::new();
            for r in rs {
                for g in ngrams(r, n).into_keys() {
                    seen.insert(g, true);
                }
            }
            for g in seen.into_keys() {
                *df[n].entry(g).or_insert(0.0) += 1.0;
            }
        }
    }
    let num_docs = refs.len().max(1) as f64;
    let tfidf = |seq: &[i32], n: usize| -> HashMap<Gram, f64> {
        let grams = ngrams(seq, n);
        let total: usize = grams.values().sum();
        grams
            .into_iter()
            .map(|(g, c)| {
                let idf = (num_docs / df[n].get(&g).copied().unwrap_or(0.0).max(1.0)).ln();
                (g, c as f64 / total.max(1) as f64 * idf)
            })
            .collect()
    };
    let cos = |a: &HashMap<Gram, f64>, b: &HashMap<Gram, f64>| -> f64 {
        let dot: f64 = a.iter().map(|(g, v)| v * b.get(g).copied().unwrap_or(0.0)).sum();
        let na: f64 = a.values().map(|v| v * v).sum::<f64>().sqrt();
        let nb: f64 = b.values().map(|v| v * v).sum::<f64>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    };
    let mut total = 0.0;
    for (h, rs) in hyps.iter().zip(refs) {
        let mut score = 0.0;
        for n in 1..=max_n {
            let hv = tfidf(h, n);
            let mut per_ref = 0.0;
            for r in rs {
                per_ref += cos(&hv, &tfidf(r, n));
            }
            score += per_ref / rs.len().max(1) as f64;
        }
        total += 10.0 * score / max_n as f64;
    }
    total / hyps.len().max(1) as f64
}

/// All five Table 3 metrics in one struct.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NlgScores {
    pub bleu: f64,
    pub nist: f64,
    pub meteor: f64,
    pub rouge_l: f64,
    pub cider: f64,
}

pub fn score_all(hyps: &[Vec<i32>], refs: &[Vec<Vec<i32>>]) -> NlgScores {
    NlgScores {
        bleu: bleu(hyps, refs),
        nist: nist(hyps, refs),
        meteor: meteor(hyps, refs),
        rouge_l: rouge_l(hyps, refs),
        cider: cider(hyps, refs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(h: &[i32], r: &[i32]) -> (Vec<Vec<i32>>, Vec<Vec<Vec<i32>>>) {
        (vec![h.to_vec()], vec![vec![r.to_vec()]])
    }

    #[test]
    fn perfect_hypothesis_maxes_metrics() {
        let r = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let (h, rs) = one(&r, &r);
        assert!((bleu(&h, &rs) - 100.0).abs() < 1e-9);
        assert!((rouge_l(&h, &rs) - 100.0).abs() < 1e-9);
        assert!((meteor(&h, &rs) - 100.0 * (1.0 - 0.5 / 64.0)).abs() < 1.0);
        assert!(nist(&h, &rs) > 0.0);
        // CIDEr needs a multi-document corpus (idf degenerates to 0 with a
        // single reference set — the standard definition).
        let hyps = vec![vec![1, 2, 3, 4], vec![5, 6, 7, 8]];
        let refs = vec![vec![vec![1, 2, 3, 4]], vec![vec![5, 6, 7, 8]]];
        assert!(cider(&hyps, &refs) > 9.0, "perfect corpus CIDEr {}", cider(&hyps, &refs));
    }

    #[test]
    fn disjoint_hypothesis_scores_zero() {
        let (h, rs) = one(&[10, 11, 12, 13], &[1, 2, 3, 4]);
        assert_eq!(bleu(&h, &rs), 0.0);
        assert_eq!(rouge_l(&h, &rs), 0.0);
        assert_eq!(meteor(&h, &rs), 0.0);
        assert!(cider(&h, &rs) < 1e-9);
    }

    #[test]
    fn bleu_brevity_penalty_kicks_in() {
        // hypothesis = first half of the reference: perfect precision but
        // short -> BP < 1.
        let r = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let (h, rs) = one(&r[..5], &r);
        let b = bleu(&h, &rs);
        assert!(b < 100.0 && b > 20.0, "bleu {b}");
    }

    #[test]
    fn rouge_order_sensitivity() {
        // same bag of words, scrambled: LCS drops.
        let (h, rs) = one(&[4, 3, 2, 1], &[1, 2, 3, 4]);
        assert!(rouge_l(&h, &rs) < 50.0);
    }

    #[test]
    fn meteor_fragmentation_penalty() {
        // contiguous match scores higher than fragmented match
        let r = vec![1, 2, 3, 4, 5, 6];
        let contiguous = meteor(&[vec![1, 2, 3]], &[vec![r.clone()]]);
        let fragmented = meteor(&[vec![1, 3, 5]], &[vec![r.clone()]]);
        assert!(contiguous > fragmented, "{contiguous} !> {fragmented}");
    }

    #[test]
    fn multiple_references_help() {
        let refs_multi = vec![vec![vec![1, 2, 3, 4], vec![4, 3, 2, 1]]];
        let refs_single = vec![vec![vec![1, 2, 3, 4]]];
        let h = vec![vec![4, 3, 2, 1]];
        assert!(bleu(&h, &refs_multi) > bleu(&h, &refs_single));
    }

    #[test]
    fn lcs_known() {
        assert_eq!(lcs(&[1, 3, 5, 7], &[1, 2, 3, 4, 5]), 3);
        assert_eq!(lcs(&[], &[1]), 0);
    }

    #[test]
    fn cider_rewards_consensus() {
        // hypothesis matching the common part of both references beats one
        // matching a single reference's idiosyncratic tail
        let refs = vec![
            vec![vec![1, 2, 3, 9, 9], vec![1, 2, 3, 8, 8]],
            vec![vec![5, 6, 7, 9, 9], vec![5, 6, 7, 8, 8]],
        ];
        let common = vec![vec![1, 2, 3], vec![5, 6, 7]];
        let tail = vec![vec![9, 9], vec![8, 8]];
        assert!(cider(&common, &refs) > cider(&tail, &refs));
    }
}
