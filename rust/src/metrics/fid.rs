//! Fréchet Inception Distance with a fixed random-feature extractor
//! (Table 13, DreamBooth-sim).
//!
//! The real FID uses InceptionV3 pool3 features; offline we substitute a
//! *fixed* (seeded) random projection of 8x8 average-pooled pixels through
//! a tanh nonlinearity — a random conv-ish feature map. Random features
//! preserve distributional distances well enough for the *relative*
//! comparisons Table 13 makes (w/o-finetune >> LoRA ≈ FourierFT > FF).
//!
//! FID = |mu_a - mu_b|^2 + Tr(Ca + Cb - 2 (Ca Cb)^{1/2}); we use the
//! diagonal-covariance form (standard for small sample counts) which keeps
//! the trace term closed-form: sum over dims of (sa + sb - 2 sqrt(sa sb)).

use crate::data::vision::IMG;
use crate::tensor::rng::Rng;

pub const FEAT_DIM: usize = 64;
const POOL: usize = 4; // 32 -> 8x8 pooling
const POOLED: usize = (IMG / POOL) * (IMG / POOL) * 3;

/// The fixed projection matrix (seeded once; same for all measurements).
fn projection() -> Vec<f32> {
    let mut rng = Rng::new(0xF1D);
    rng.normal_vec(POOLED * FEAT_DIM, (POOLED as f32).powf(-0.5))
}

/// Feature vector of one image (pixels: IMG*IMG*3 HWC in [0,1]).
pub fn features(pixels: &[f32]) -> Vec<f32> {
    assert_eq!(pixels.len(), IMG * IMG * 3);
    // 4x4 average pool per channel
    let g = IMG / POOL;
    let mut pooled = vec![0.0f32; POOLED];
    for y in 0..IMG {
        for x in 0..IMG {
            for c in 0..3 {
                let v = pixels[(y * IMG + x) * 3 + c];
                pooled[((y / POOL) * g + (x / POOL)) * 3 + c] += v / (POOL * POOL) as f32;
            }
        }
    }
    let proj = projection();
    let mut out = vec![0.0f32; FEAT_DIM];
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (j, &p) in pooled.iter().enumerate() {
            acc += p * proj[j * FEAT_DIM + i];
        }
        *o = acc.tanh();
    }
    out
}

fn moments(feats: &[Vec<f32>]) -> (Vec<f64>, Vec<f64>) {
    let n = feats.len().max(1) as f64;
    let mut mu = vec![0.0f64; FEAT_DIM];
    for f in feats {
        for (m, &v) in mu.iter_mut().zip(f) {
            *m += v as f64 / n;
        }
    }
    let mut var = vec![0.0f64; FEAT_DIM];
    for f in feats {
        for i in 0..FEAT_DIM {
            let d = f[i] as f64 - mu[i];
            var[i] += d * d / n;
        }
    }
    (mu, var)
}

/// FID between two image sets (each: vec of IMG*IMG*3 pixel buffers).
pub fn fid(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    let fa: Vec<Vec<f32>> = a.iter().map(|p| features(p)).collect();
    let fb: Vec<Vec<f32>> = b.iter().map(|p| features(p)).collect();
    let (mu_a, var_a) = moments(&fa);
    let (mu_b, var_b) = moments(&fb);
    let mut d2 = 0.0;
    let mut tr = 0.0;
    for i in 0..FEAT_DIM {
        let dm = mu_a[i] - mu_b[i];
        d2 += dm * dm;
        tr += var_a[i] + var_b[i] - 2.0 * (var_a[i] * var_b[i]).sqrt();
    }
    // scale to the familiar FID magnitude range
    100.0 * (d2 + tr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vision::VisionSet;

    fn images(set: VisionSet, class: usize, count: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..count).map(|_| set.render(class, &mut rng).pixels).collect()
    }

    #[test]
    fn identical_distributions_give_near_zero() {
        let a = images(VisionSet::Cifar10, 3, 64, 1);
        let b = images(VisionSet::Cifar10, 3, 64, 2);
        let d = fid(&a, &b);
        assert!(d < 5.0, "same-distribution FID {d}");
    }

    #[test]
    fn different_classes_give_larger_fid() {
        let a = images(VisionSet::Cifar10, 3, 64, 1);
        let b = images(VisionSet::Cifar10, 7, 64, 2);
        let same = fid(&a, &images(VisionSet::Cifar10, 3, 64, 3));
        let diff = fid(&a, &b);
        assert!(diff > 4.0 * same.max(0.05), "same {same} vs diff {diff}");
    }

    #[test]
    fn fid_is_symmetric() {
        let a = images(VisionSet::Dtd47, 1, 32, 1);
        let b = images(VisionSet::Dtd47, 20, 32, 2);
        assert!((fid(&a, &b) - fid(&b, &a)).abs() < 1e-9);
    }

    #[test]
    fn features_are_deterministic() {
        let a = images(VisionSet::Pets37, 0, 1, 9);
        assert_eq!(features(&a[0]), features(&a[0]));
    }
}
