//! Classification metrics: accuracy, binary F1, Matthews correlation.

/// Fraction of positions where prediction == label.
pub fn accuracy(pred: &[i32], label: &[i32]) -> f64 {
    assert_eq!(pred.len(), label.len());
    if pred.is_empty() {
        return 0.0;
    }
    let ok = pred.iter().zip(label).filter(|(p, l)| p == l).count();
    ok as f64 / pred.len() as f64
}

/// Binary F1 with class 1 as positive.
pub fn f1_binary(pred: &[i32], label: &[i32]) -> f64 {
    let (mut tp, mut fp, mut fn_) = (0.0, 0.0, 0.0);
    for (&p, &l) in pred.iter().zip(label) {
        match (p == 1, l == 1) {
            (true, true) => tp += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fn_ += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fn_);
    2.0 * precision * recall / (precision + recall)
}

/// Matthews correlation coefficient (the CoLA metric).
pub fn matthews(pred: &[i32], label: &[i32]) -> f64 {
    let (mut tp, mut tn, mut fp, mut fn_) = (0.0f64, 0.0, 0.0, 0.0);
    for (&p, &l) in pred.iter().zip(label) {
        match (p == 1, l == 1) {
            (true, true) => tp += 1.0,
            (false, false) => tn += 1.0,
            (true, false) => fp += 1.0,
            (false, true) => fn_ += 1.0,
        }
    }
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (tp * tn - fp * fn_) / denom
}

/// Argmax over the last axis of row-major logits [n, c].
pub fn argmax_rows(logits: &[f32], classes: usize) -> Vec<i32> {
    logits
        .chunks_exact(classes)
        .map(|row| {
            let mut best = (0usize, f32::MIN);
            for (i, &v) in row.iter().enumerate() {
                if v > best.1 {
                    best = (i, v);
                }
            }
            best.0 as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert_eq!(f1_binary(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1_binary(&[0, 0], &[1, 1]), 0.0);
    }

    #[test]
    fn matthews_known_value() {
        // TP=2 TN=2 FP=1 FN=1 -> mcc = (4-1)/sqrt(3*3*3*3) = 1/3
        let pred = [1, 1, 1, 0, 0, 0];
        let label = [1, 1, 0, 0, 0, 1];
        assert!((matthews(&pred, &label) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn matthews_perfect_is_one_inverted_is_minus_one() {
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        assert!((matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_rows_works() {
        let logits = [0.1, 0.9, 0.0, 0.8, 0.1, 0.1];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }
}
