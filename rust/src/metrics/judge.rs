//! MT-Bench-sim judge: the deterministic stand-in for GPT-4 scoring
//! (Table 4). A response to an instruction question earns a 0-10 score:
//!
//! * 10 x (token-level accuracy against the exact answer), with
//! * a 2-point deduction for wrong response length (truncated/rambling),
//!   floored at 0 — mirroring how GPT-4 penalizes incomplete answers.
//!
//! The *relative* comparison between fine-tuning methods (what Table 4 is
//! about) is preserved: a better-tuned model produces more exact-match
//! responses and earns a higher mean score.

use crate::data::instruct::Question;
use crate::data::vocab::EOS;

/// Score one response (generated token stream, EOS-terminated or ragged).
pub fn score_response(q: &Question, response: &[i32]) -> f64 {
    let want = q.answer(); // includes EOS
    // cut the response at its first EOS (inclusive)
    let cut = response
        .iter()
        .position(|&t| t == EOS)
        .map(|i| i + 1)
        .unwrap_or(response.len());
    let got = &response[..cut];
    let matched = want
        .iter()
        .zip(got.iter())
        .filter(|(a, b)| a == b)
        .count();
    let acc = matched as f64 / want.len() as f64;
    let mut score = 10.0 * acc;
    if got.len() != want.len() {
        score -= 2.0;
    }
    score.clamp(0.0, 10.0)
}

/// Mean score over a question set, given per-question responses.
pub fn mean_score(questions: &[Question], responses: &[Vec<i32>]) -> f64 {
    assert_eq!(questions.len(), responses.len());
    if questions.is_empty() {
        return 0.0;
    }
    let total: f64 = questions
        .iter()
        .zip(responses)
        .map(|(q, r)| score_response(q, r))
        .sum();
    total / questions.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::instruct::Op;
    use crate::data::vocab::{vocab, Class};

    fn q() -> Question {
        let nums = vocab().ids_of(Class::Number);
        Question { op: Op::Reverse, input: vec![nums[1], nums[2], nums[3]] }
    }

    #[test]
    fn exact_answer_scores_ten() {
        let question = q();
        let resp = question.answer();
        assert_eq!(score_response(&question, &resp), 10.0);
    }

    #[test]
    fn empty_answer_scores_zero() {
        assert_eq!(score_response(&q(), &[]), 0.0);
    }

    #[test]
    fn partial_answer_scores_between() {
        let question = q();
        let mut resp = question.answer();
        let nums = vocab().ids_of(Class::Number);
        resp[0] = nums[9]; // corrupt first token
        let s = score_response(&question, &resp);
        assert!(s > 0.0 && s < 10.0, "score {s}");
    }

    #[test]
    fn rambling_is_penalized() {
        let question = q();
        let mut resp = question.answer();
        resp.pop(); // remove EOS
        resp.extend([resp[0], resp[0], resp[0]]); // ramble, no EOS
        let exact = score_response(&question, &question.answer());
        assert!(score_response(&question, &resp) < exact);
    }

    #[test]
    fn mean_over_set() {
        let qs = vec![q(), q()];
        let rs = vec![qs[0].answer(), vec![]];
        assert_eq!(mean_score(&qs, &rs), 5.0);
    }
}
