//! The simulated cluster: N nodes in one process, each a full serving
//! stack, plus the failure / rebalance scenario machinery.
//!
//! Every [`Node`] owns the same trio a real serving host would — a
//! [`SharedAdapterStore`] over its own directory, a [`SharedSwap`] cache
//! stack, and (during a serve call) a scheduler worker pool — so the
//! cluster layer composes *only* public single-node entry points and
//! inherits their determinism proofs wholesale. A serve call is:
//!
//! 1. **pin** — one [`VersionFence::pin_map`] snapshot rewrites every
//!    request to `name@v` (PR 5 semantics): the generation each request
//!    will observe is fixed at admission, before placement.
//! 2. **admit globally** — the user's [`AdmissionCfg`] runs once over
//!    the full arrival sequence (see [`crate::cluster::router`] for why
//!    per-node admission would break digest invariance).
//! 3. **promote + route** — observed counts widen hot adapters' replica
//!    sets ([`placement::replica_counts`]), missing replica bytes are
//!    synced, and the router assigns every offered request to a node.
//! 4. **serve per node** — each node runs
//!    [`serve_open_loop_host`] over its sub-queue with a *never-shed*
//!    admission config (the global pass already decided shedding; the
//!    node keeps the caller's `service_ticks`/`flush_slack_ticks` so
//!    virtual-time flush behavior matches the single-node path exactly).
//!    Nodes execute sequentially — each simulated node notionally owns a
//!    whole machine, so cluster makespan is the *max* per-node wall
//!    ([`ClusterStats::wall_max_seconds`]), not the sum, and per-node
//!    runs never contend for the test host's cores.
//! 5. **aggregate** — results merge id-sorted; per-node [`ServeStats`]
//!    fold into a cluster total via [`ServeStats::merge`] (sums for
//!    offered/shed/goodput, maxes for `queue_depth_peak`/`peak_bytes`).
//!
//! Failures are fail-stop at a tick: a node with `failed_at = T` serves
//! the requests routed to it that arrived before `T`; arrivals at or
//! after `T` deterministically fail over to the next live replica.
//! [`Cluster::rebalance`] then removes dead nodes from the ring and
//! syncs exactly the keys whose replica sets changed (≈1/N — the
//! consistent-hashing payoff), with the cold-cache refill on the new
//! owners observable through [`SwapCacheStats`].

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::adapter::store::versioned_ref;
use crate::adapter::{AdapterFile, SharedAdapterStore};
use crate::cluster::fence::VersionFence;
use crate::cluster::placement::{self, moved_keys, Ring};
use crate::cluster::router::{self, RoutePlan};
use crate::coordinator::scheduler::{
    admit, serve_open_loop_host, AdmissionCfg, SchedCfg, ShedReason,
};
use crate::coordinator::serving::{ServeStats, SharedSwap, SwapCacheStats, TimedRequest};
use crate::coordinator::workload::{pin_timed_requests, populate_store, site_dims, WorkloadCfg};
use crate::tensor::Tensor;

/// Cluster shape + policy knobs. Everything downstream of these is
/// deterministic, so two clusters built from equal configs and equal
/// workloads are bitwise-interchangeable.
#[derive(Debug, Clone)]
pub struct ClusterCfg {
    pub nodes: usize,
    /// Base replication factor R (clamped to the live node count).
    pub replicas: usize,
    /// Virtual-node points per node on the placement ring.
    pub vnodes: usize,
    /// Extra replicas granted to promoted-hot adapters (0 disables).
    pub hot_extra: usize,
    /// Promote when an adapter's observed request count exceeds
    /// `hot_factor ×` the mean count.
    pub hot_factor: f64,
    /// Store / swap shards per node (lock partitioning within a node).
    pub store_shards: usize,
    /// Decode/swap cache capacity per shard per node.
    pub cache_cap: usize,
    /// Publish history retained per adapter per node (keep-K GC).
    pub keep_versions: usize,
    /// Fail-stop schedule: `(tick, node)` — the node serves arrivals
    /// strictly before the tick, never at or after it.
    pub fail_at: Vec<(u64, usize)>,
}

impl ClusterCfg {
    pub fn new(nodes: usize, replicas: usize) -> ClusterCfg {
        ClusterCfg {
            nodes,
            replicas,
            vnodes: 64,
            hot_extra: 1,
            hot_factor: 8.0,
            store_shards: 4,
            cache_cap: 64,
            keep_versions: 4,
            fail_at: Vec::new(),
        }
    }
}

/// One simulated serving node: its own store directory, cache stack,
/// and fail-stop status.
pub struct Node {
    pub id: usize,
    pub store: SharedAdapterStore,
    pub swap: SharedSwap,
    /// Fail-stop tick, if scheduled: the node is dead for arrivals at or
    /// after this tick.
    pub failed_at: Option<u64>,
}

impl Node {
    pub fn live_at(&self, tick: u64) -> bool {
        self.failed_at.is_none_or(|t| tick < t)
    }
}

/// Per-wave rebalance / membership-change outcome.
#[derive(Debug, Default)]
pub struct RebalanceReport {
    /// Node ids removed from the ring (fail-stop cleanup).
    pub removed: Vec<usize>,
    /// Adapters whose replica set changed (the consistent-hash movement
    /// bound says ≈ keys/N of these per membership change).
    pub moved: usize,
    /// `(adapter, node)` replica copies actually transferred (a move is
    /// free when the target already holds the pinned version).
    pub synced: usize,
}

/// Cluster-level accounting for one serve wave.
pub struct ClusterStats {
    /// Per-node serve stats, indexed by node id. Dead / unrouted nodes
    /// hold a default entry, so sums over this vector are exact.
    pub per_node: Vec<ServeStats>,
    /// Per-node swap-cache snapshots taken after the wave.
    pub per_node_swap: Vec<SwapCacheStats>,
    /// [`ServeStats::merge`] fold over `per_node`: offered / shed /
    /// goodput sum exactly to the global admission figures;
    /// `queue_depth_peak` / `peak_bytes` are cross-node maxes;
    /// `wall_seconds` is the *sum* of per-node walls (node-seconds).
    pub total: ServeStats,
    /// Max per-node wall — the cluster makespan under the one-machine-
    /// per-node model, and the denominator of [`ClusterStats::goodput_rps`].
    pub wall_max_seconds: f64,
    /// Requests re-routed off a dead replica pick.
    pub failovers: usize,
    /// Adapters promoted to extra replicas this wave.
    pub promoted: Vec<String>,
    /// Replica copies transferred to back the promotions.
    pub synced: usize,
}

impl ClusterStats {
    /// Deadline-met requests per second of cluster makespan — the
    /// scale-out figure of merit (`cluster/scaleout/*` bench rows).
    pub fn goodput_rps(&self) -> f64 {
        if self.wall_max_seconds > 0.0 {
            self.total.goodput as f64 / self.wall_max_seconds
        } else {
            0.0
        }
    }

    /// Served requests per second of cluster makespan.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_max_seconds > 0.0 {
            self.total.requests as f64 / self.wall_max_seconds
        } else {
            0.0
        }
    }
}

/// The simulated cluster. See the module docs for the serve pipeline.
pub struct Cluster {
    pub cfg: ClusterCfg,
    pub nodes: Vec<Node>,
    pub ring: Ring,
    pub fence: VersionFence,
    dir: PathBuf,
    site_dims: BTreeMap<String, (usize, usize)>,
    names: Vec<String>,
}

impl Cluster {
    /// Build an N-node cluster under `dir`: every node gets its own
    /// store directory populated with the workload's seeded adapters
    /// (bit-identical across nodes — the generator is name-seeded) and
    /// version 1 of each published, so `name@1` resolves identically
    /// everywhere; the fence starts at v1 for every name. Any existing
    /// `dir` contents are removed first.
    pub fn build(dir: &Path, wl: &WorkloadCfg, cfg: ClusterCfg) -> Result<Cluster> {
        ensure!(cfg.nodes > 0, "cluster needs at least one node");
        ensure!(cfg.replicas > 0, "replication factor must be >= 1");
        for &(tick, node) in &cfg.fail_at {
            ensure!(node < cfg.nodes, "fail-at tick {tick} names unknown node {node}");
        }
        let _ = std::fs::remove_dir_all(dir);
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating cluster dir {}", dir.display()))?;
        let dims = site_dims(wl);
        let mut nodes = Vec::with_capacity(cfg.nodes);
        let mut names = Vec::new();
        for id in 0..cfg.nodes {
            let node = make_node(dir, id, &dims, &cfg)?;
            names = populate_store(&node.store, wl)?;
            for name in &names {
                let file = node.store.load(name)?;
                let (v, _) = node.store.publish(name, &file)?;
                ensure!(v == 1, "fresh node {id} published '{name}' at v{v}, expected v1");
            }
            nodes.push(node);
        }
        let mut cluster = Cluster {
            ring: Ring::new(&(0..cfg.nodes).collect::<Vec<_>>(), cfg.vnodes),
            fence: VersionFence::new(names.iter().map(|n| (n.clone(), 1))),
            nodes,
            cfg,
            dir: dir.to_path_buf(),
            site_dims: dims,
            names,
        };
        let schedule = cluster.cfg.fail_at.clone();
        for (tick, node) in schedule {
            cluster.fail_node(node, tick);
        }
        Ok(cluster)
    }

    /// Adapter base names the cluster was built with.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// The base replica set of `name` (ring order, primary first),
    /// clamped to the ring size.
    pub fn owners(&self, name: &str) -> Vec<usize> {
        self.ring.replicas(name, self.cfg.replicas)
    }

    /// Serve one open-loop wave: pin → admit globally → promote + route →
    /// per-node serve → aggregate. Returns the id-sorted responses and
    /// the cluster accounting. Responses are bitwise-invariant to node
    /// count, replication factor, and the failure schedule (survivors
    /// serve the same immutable `name@v` bytes); the shed-id set is
    /// decided by the global admission pass and shared by all shapes.
    pub fn serve_open_loop(
        &self,
        mut queue: Vec<TimedRequest>,
        cfg: &SchedCfg,
        adm: &AdmissionCfg,
    ) -> Result<(Vec<(u64, Tensor)>, ClusterStats)> {
        let pins = self.fence.pin_map();
        pin_timed_requests(&mut queue, |name| pins.get(name).copied());
        let admission = admit(queue.clone(), adm);

        // Hot promotion from observed counts, then make sure every
        // promoted extra replica holds the pinned bytes before routing.
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for tr in &queue {
            let (base, _) = crate::adapter::store::split_versioned(&tr.req.adapter);
            *counts.entry(base.to_string()).or_insert(0) += 1;
        }
        let promoted = placement::replica_counts(
            &counts,
            self.cfg.replicas,
            self.cfg.hot_extra,
            self.cfg.hot_factor,
        );
        let mut synced = 0usize;
        for (name, &r) in &promoted {
            let wide = self.ring.replicas(name, r);
            for &extra in wide.iter().skip(self.cfg.replicas.min(wide.len())) {
                if self.sync_to(name, extra)? {
                    synced += 1;
                }
            }
        }

        let plan = router::route(
            &self.ring,
            self.nodes.len(),
            queue,
            &admission.shed,
            self.cfg.replicas,
            &promoted,
            |n, t| self.nodes[n].live_at(t),
        )?;
        let (results, stats) = self.run_plan(plan, cfg, adm, promoted, synced)?;
        Ok((results, stats))
    }

    /// Execute a route plan node by node and aggregate. The per-node
    /// admission config never sheds (depth unbounded, rate limit off) —
    /// the global pass already decided the shed set — but keeps the
    /// caller's virtual-time parameters so flush / goodput accounting
    /// matches the single-node scheduler exactly.
    fn run_plan(
        &self,
        mut plan: RoutePlan,
        cfg: &SchedCfg,
        adm: &AdmissionCfg,
        promoted: BTreeMap<String, usize>,
        synced: usize,
    ) -> Result<(Vec<(u64, Tensor)>, ClusterStats)> {
        let node_adm = AdmissionCfg {
            service_ticks: adm.service_ticks,
            queue_depth: usize::MAX,
            tenant_rate_per_ktick: 0.0,
            tenant_burst: adm.tenant_burst,
            flush_slack_ticks: adm.flush_slack_ticks,
        };
        let mut results: Vec<(u64, Tensor)> = Vec::new();
        let mut per_node: Vec<ServeStats> = Vec::with_capacity(self.nodes.len());
        let mut per_node_swap: Vec<SwapCacheStats> = Vec::with_capacity(self.nodes.len());
        let mut wall_max = 0.0f64;
        for node in &self.nodes {
            let sub = std::mem::take(&mut plan.per_node[node.id]);
            let mut stats = if sub.is_empty() {
                ServeStats::default()
            } else {
                let (res, stats) =
                    serve_open_loop_host(&node.swap, &node.store, sub, cfg, &node_adm)?;
                results.extend(res);
                stats
            };
            // Fold the shed requests attributed to this node: the global
            // admission shed them, so the node's own (never-shed) pass
            // did not see them; per-node offered/shed must still sum to
            // the global figures.
            for (id, tenant, reason) in std::mem::take(&mut plan.shed_per_node[node.id]) {
                stats.offered += 1;
                stats.shed += 1;
                match reason {
                    ShedReason::QueueFull => stats.shed_queue_full += 1,
                    ShedReason::RateLimited => stats.shed_rate_limited += 1,
                }
                stats.shed_ids.push(id);
                match stats.per_tenant_shed.iter_mut().find(|(t, _)| *t == tenant) {
                    Some((_, c)) => *c += 1,
                    None => stats.per_tenant_shed.push((tenant, 1)),
                }
            }
            stats.shed_ids.sort_unstable();
            wall_max = wall_max.max(stats.wall_seconds);
            per_node_swap.push(node.swap.stats());
            per_node.push(stats);
        }
        results.sort_unstable_by_key(|&(id, _)| id);
        let mut total = ServeStats::default();
        for s in &per_node {
            total.merge(s.clone());
        }
        Ok((
            results,
            ClusterStats {
                per_node,
                per_node_swap,
                total,
                wall_max_seconds: wall_max,
                failovers: plan.failovers,
                promoted: promoted.into_keys().collect(),
                synced,
            },
        ))
    }

    /// Two-phase publish: stage the new generation on every base replica
    /// of `name` (the first replica's store assigns the version number;
    /// the rest install its identical stamped bytes), then atomically
    /// flip the fence. Requests admitted before the flip keep resolving
    /// the old `name@v` on every replica; requests after pin the new one.
    pub fn publish(&self, name: &str, adapter: &AdapterFile) -> Result<u64> {
        let owners = self.owners(name);
        ensure!(!owners.is_empty(), "publish of '{name}' on an empty ring");
        for &node in &owners {
            self.stage_on(node, name, adapter)?;
        }
        self.flip(name)
    }

    /// Phase 1 on one replica. The first stager runs a real
    /// [`SharedAdapterStore::publish`] (assigning `current + 1`); later
    /// stagers copy the staged bytes from a node that already has them,
    /// so every replica holds the byte-identical stamped file. `adapter`
    /// is only read by the first stager. Idempotent per (name, node).
    pub fn stage_on(&self, node: usize, name: &str, adapter: &AdapterFile) -> Result<u64> {
        ensure!(node < self.nodes.len(), "stage on unknown node {node}");
        let v = match self.fence.staged(name) {
            None => self.nodes[node].store.publish(name, adapter)?.0,
            Some((v, have)) => {
                if have.contains(&node) {
                    return Ok(v);
                }
                let src = *have.first().context("staged entry with no holder")?;
                let file = self.nodes[src].store.load(&versioned_ref(name, v))?;
                self.nodes[node].store.install_version(name, &file)?
            }
        };
        self.fence.note_staged(name, v, node)?;
        Ok(v)
    }

    /// Phase 2: flip the fence to the staged generation. Fails (leaving
    /// the old generation serving) unless every current base replica has
    /// staged it.
    pub fn flip(&self, name: &str) -> Result<u64> {
        self.fence.flip(name, &self.owners(name))
    }

    /// Schedule / record a fail-stop: the node serves arrivals strictly
    /// before `tick` and nothing after. Keeps the earliest tick if
    /// already scheduled. The ring keeps the node's points until
    /// [`Cluster::rebalance`] — routing works around the corpse via
    /// failover in the meantime, which is exactly the degraded window a
    /// real cluster has between a crash and its repair action.
    pub fn fail_node(&mut self, node: usize, tick: u64) {
        if let Some(n) = self.nodes.get_mut(node) {
            n.failed_at = Some(n.failed_at.map_or(tick, |t| t.min(tick)));
        }
    }

    /// Remove every failed node from the ring and copy the adapters
    /// whose replica sets gained a survivor owner. Movement is the
    /// consistent-hash minimum (only arcs adjacent to the dead nodes'
    /// points change hands); the transfer count is reported so tests can
    /// pin the ≈keys/N bound, and the new owners' cold caches refill on
    /// the next wave (visible in [`SwapCacheStats`]).
    pub fn rebalance(&mut self) -> Result<RebalanceReport> {
        let before = self.ring.clone();
        let removed: Vec<usize> = self
            .nodes
            .iter()
            .filter(|n| n.failed_at.is_some() && self.ring.contains(n.id))
            .map(|n| n.id)
            .collect();
        for &id in &removed {
            self.ring.remove_node(id);
        }
        ensure!(!self.ring.nodes().is_empty(), "rebalance would remove every node");
        self.sync_moved(&before, removed)
    }

    /// Bring one fresh (empty-store) node into the ring and copy it the
    /// adapters it now owns — ≈keys/(N+1) of them, everything else stays
    /// put. Returns the new node id and the movement report.
    pub fn join_node(&mut self) -> Result<(usize, RebalanceReport)> {
        let id = self.nodes.len();
        let node = make_node(&self.dir, id, &self.site_dims, &self.cfg)?;
        self.nodes.push(node);
        let before = self.ring.clone();
        self.ring.add_node(id);
        let report = self.sync_moved(&before, Vec::new())?;
        Ok((id, report))
    }

    fn sync_moved(&self, before: &Ring, removed: Vec<usize>) -> Result<RebalanceReport> {
        let moved = moved_keys(before, &self.ring, &self.names, self.cfg.replicas);
        let mut synced = 0usize;
        for (name, new_owners) in &moved {
            for &to in new_owners {
                if self.sync_to(name, to)? {
                    synced += 1;
                }
            }
        }
        Ok(RebalanceReport { removed, moved: moved.len(), synced })
    }

    /// Copy the fence-pinned generation of `name` onto node `to` from
    /// any survivor that holds it. Returns false (no copy) when `to`
    /// already has the version. Sources exclude nodes with a scheduled
    /// fail-stop: replica repair must work from survivors only.
    fn sync_to(&self, name: &str, to: usize) -> Result<bool> {
        let v = self
            .fence
            .pinned(name)
            .with_context(|| format!("sync of unknown adapter '{name}'"))?;
        if self.nodes[to].store.versions(name)?.contains(&v) {
            return Ok(false);
        }
        let src = self
            .nodes
            .iter()
            .find(|n| {
                n.id != to
                    && n.failed_at.is_none()
                    && n.store.versions(name).map(|vs| vs.contains(&v)).unwrap_or(false)
            })
            .with_context(|| format!("no live source holds '{name}@{v}' for node {to}"))?;
        let file = src.store.load(&versioned_ref(name, v))?;
        self.nodes[to].store.install_version(name, &file)?;
        Ok(true)
    }
}

/// One node's directory + store + swap. `populate` happens at the call
/// site: build fills every node; join starts empty (cold) and receives
/// only the keys it owns via sync.
fn make_node(
    dir: &Path,
    id: usize,
    dims: &BTreeMap<String, (usize, usize)>,
    cfg: &ClusterCfg,
) -> Result<Node> {
    let ndir = dir.join(format!("node{id}"));
    let store = SharedAdapterStore::with_shards_keep(
        &ndir,
        cfg.store_shards,
        cfg.cache_cap,
        cfg.keep_versions,
    )?;
    let swap = SharedSwap::with_shards(dims.clone(), cfg.store_shards, cfg.cache_cap);
    Ok(Node { id, store, swap, failed_at: None })
}
