//! Admission-side request routing: pinned queue → per-node sub-queues.
//!
//! Ordering is the whole design: **pin → admit globally → place**.
//!
//! Admission runs *once*, over the full arrival sequence, with the
//! user's [`crate::coordinator::scheduler::AdmissionCfg`] — before any
//! placement decision. Admission is a pure function of the arrival
//! sequence (PR 7), so the shed set — and therefore the shed-id digest
//! the CI gate compares — is invariant to node count, replication
//! factor, and failure schedule. Running admission per node instead
//! would make the shed set a function of placement (each node sees a
//! thinner arrival stream), and `--nodes 1` vs `--nodes 4` would shed
//! different requests: exactly the non-determinism the contract forbids.
//!
//! Placement then routes every offered request (admitted *and* shed) to
//! one node: shed requests are attributed to the node that would have
//! served them, so per-node `offered`/`shed` counters sum exactly to the
//! global figures ([`crate::cluster::ClusterStats`] relies on this).
//!
//! The replica pick hashes `(base name, request id)` — one adapter's
//! traffic spreads across its replica set, hot-promoted adapters across
//! a wider one — and fails over deterministically when the picked
//! replica is dead at the request's arrival tick: first to the live
//! members of the replica set, then (R=1 or all replicas dead) to the
//! first live node on the full ring walk. Which node serves a request
//! can depend on the failure schedule; the response bits cannot, because
//! every candidate resolves the same immutable `name@v` file.

use std::collections::{BTreeMap, HashMap};

use anyhow::{ensure, Result};

use crate::adapter::store::split_versioned;
use crate::cluster::placement::Ring;
use crate::coordinator::scheduler::ShedReason;
use crate::coordinator::serving::TimedRequest;
use crate::util::hash::{fnv64, fnv64_fold_u64};

/// The routing outcome: one admitted sub-queue and one attributed shed
/// list per node slot (indexed by node id; dead or unused slots hold
/// empty vectors).
pub struct RoutePlan {
    /// Admitted requests per node, in arrival order.
    pub per_node: Vec<Vec<TimedRequest>>,
    /// Shed requests attributed to the node that would have served them.
    pub shed_per_node: Vec<Vec<(u64, String, ShedReason)>>,
    /// Requests whose hashed replica pick was dead at their arrival tick
    /// and were re-routed to another live node.
    pub failovers: usize,
}

/// Route every offered request to a node. `shed` is the global
/// admission's shed list (`(id, tenant, reason)`); requests whose id
/// appears there land in `shed_per_node` instead of a serve queue.
/// `replicas` is the base replication factor, widened per adapter by the
/// `promoted` plan ([`crate::cluster::placement::replica_counts`]).
/// `live_at(node, tick)` is the fail-stop oracle.
pub fn route(
    ring: &Ring,
    node_slots: usize,
    queue: Vec<TimedRequest>,
    shed: &[(u64, String, ShedReason)],
    replicas: usize,
    promoted: &BTreeMap<String, usize>,
    live_at: impl Fn(usize, u64) -> bool,
) -> Result<RoutePlan> {
    let shed_reason: HashMap<u64, ShedReason> =
        shed.iter().map(|&(id, _, reason)| (id, reason)).collect();
    let mut plan = RoutePlan {
        per_node: (0..node_slots).map(|_| Vec::new()).collect(),
        shed_per_node: (0..node_slots).map(|_| Vec::new()).collect(),
        failovers: 0,
    };
    for tr in queue {
        let (base, _) = split_versioned(&tr.req.adapter);
        let r = promoted.get(base).copied().unwrap_or(replicas).max(1);
        let cands = ring.replicas(base, r);
        ensure!(!cands.is_empty(), "cannot route '{base}': ring has no nodes");
        let spread = fnv64_fold_u64(fnv64(base), tr.req.id);
        let mut node = cands[(spread % cands.len() as u64) as usize];
        if !live_at(node, tr.arrive_tick) {
            plan.failovers += 1;
            let live: Vec<usize> =
                cands.iter().copied().filter(|&n| live_at(n, tr.arrive_tick)).collect();
            node = if let Some(&n) = live.get((spread % live.len().max(1) as u64) as usize) {
                n
            } else {
                // Whole replica set dead: walk the full ring for any
                // survivor so R=1 clusters degrade instead of erroring.
                let walk = ring.replicas(base, ring.nodes().len());
                match walk.into_iter().find(|&n| live_at(n, tr.arrive_tick)) {
                    Some(n) => n,
                    None => anyhow::bail!(
                        "no live node for '{base}' at tick {} — whole cluster is down",
                        tr.arrive_tick
                    ),
                }
            };
        }
        ensure!(node < node_slots, "ring node {node} outside cluster slots 0..{node_slots}");
        match shed_reason.get(&tr.req.id) {
            Some(&reason) => {
                plan.shed_per_node[node].push((tr.req.id, tr.req.adapter.clone(), reason))
            }
            None => plan.per_node[node].push(tr),
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serving::Request;
    use crate::tensor::Tensor;

    fn tr(id: u64, adapter: &str, tick: u64) -> TimedRequest {
        let mut batch = crate::coordinator::trainer::Batch::new();
        batch.insert("x".to_string(), Tensor::zeros(&[1, 2]));
        TimedRequest {
            arrive_tick: tick,
            deadline_tick: tick + 64,
            req: Request { id, adapter: adapter.to_string(), batch },
        }
    }

    #[test]
    fn routing_is_deterministic_and_respects_replica_sets() {
        let ring = Ring::new(&[0, 1, 2, 3], 32);
        let queue: Vec<TimedRequest> =
            (0..200).map(|i| tr(i, &format!("zipf_{:04}@1", i % 7), i)).collect();
        let plan = route(&ring, 4, queue.clone(), &[], 2, &BTreeMap::new(), |_, _| true).unwrap();
        let plan2 = route(&ring, 4, queue, &[], 2, &BTreeMap::new(), |_, _| true).unwrap();
        assert_eq!(plan.failovers, 0);
        for n in 0..4 {
            let ids: Vec<u64> = plan.per_node[n].iter().map(|t| t.req.id).collect();
            let ids2: Vec<u64> = plan2.per_node[n].iter().map(|t| t.req.id).collect();
            assert_eq!(ids, ids2, "same inputs must route identically");
            for t in &plan.per_node[n] {
                let (base, _) = split_versioned(&t.req.adapter);
                assert!(
                    ring.replicas(base, 2).contains(&n),
                    "request for {base} routed off its replica set"
                );
            }
        }
        assert_eq!(plan.per_node.iter().map(Vec::len).sum::<usize>(), 200);
    }

    #[test]
    fn dead_replica_fails_over_to_live_one_after_its_tick() {
        let ring = Ring::new(&[0, 1], 32);
        let queue: Vec<TimedRequest> = (0..100).map(|i| tr(i, "zipf_0000@1", i)).collect();
        let fail_tick = 50;
        let alive = |n: usize, t: u64| n != 1 || t < fail_tick;
        let plan = route(&ring, 2, queue, &[], 2, &BTreeMap::new(), alive).unwrap();
        for t in &plan.per_node[1] {
            assert!(t.arrive_tick < fail_tick, "dead node got a post-failure request");
        }
        let served: usize = plan.per_node.iter().map(Vec::len).sum();
        assert_eq!(served, 100, "failover must not drop requests");
    }

    #[test]
    fn shed_requests_are_attributed_not_served() {
        let ring = Ring::new(&[0, 1], 32);
        let queue: Vec<TimedRequest> = (0..20).map(|i| tr(i, "zipf_0001@1", i)).collect();
        let shed = vec![(3u64, "zipf_0001@1".to_string(), ShedReason::QueueFull)];
        let plan = route(&ring, 2, queue, &shed, 1, &BTreeMap::new(), |_, _| true).unwrap();
        let served: usize = plan.per_node.iter().map(Vec::len).sum();
        let attributed: usize = plan.shed_per_node.iter().map(Vec::len).sum();
        assert_eq!((served, attributed), (19, 1));
        assert!(plan.per_node.iter().flatten().all(|t| t.req.id != 3));
    }
}
