//! Sharded multi-node serving cluster, simulated in one process.
//!
//! FourierFT's ~0.06M-parameter adapters make fleets of *millions* of
//! per-user adapters realistic — the regime where a single-process
//! scheduler stops being the story and placement/routing across nodes
//! becomes the system. This layer sits above the single-node stack
//! (PR 5 versioned lifecycle, PR 6 factored residency, PR 7 open-loop
//! admission) and composes it unmodified:
//!
//! * [`placement`] — consistent-hash ring (virtual nodes, R-way
//!   replication, Zipf-hot promotion from observed counts);
//! * [`router`] — pin → admit globally → place: deterministic replica
//!   pick per request, with fail-stop failover;
//! * [`fence`] — two-phase publish propagation (stage on all replicas,
//!   atomically flip) so no request ever observes a mixed generation;
//! * [`sim`] — the [`Cluster`] itself: N nodes, each with its own
//!   [`crate::adapter::SharedAdapterStore`] +
//!   [`crate::coordinator::serving::SharedSwap`] + scheduler pool, plus
//!   seeded failure / join / rebalance scenarios and [`ClusterStats`]
//!   aggregation.
//!
//! **The determinism contract, inherited not invented:** a request
//! pinned at admission (`name@v`) produces a bitwise-identical response
//! regardless of which replica serves it, how many nodes exist, or what
//! the failure schedule was (survivors only) — because every replica
//! resolves the same immutable version file and the single-node
//! scheduler is already bitwise-deterministic (`tests/open_loop.rs`).
//! The shed-id set is likewise invariant: admission runs once, globally,
//! before placement. `tests/cluster.rs` pins both across
//! `nodes {1,2,4} × replicas {1,2}`, failure schedules, and re-runs.

pub mod fence;
pub mod placement;
pub mod router;
pub mod sim;

pub use fence::VersionFence;
pub use placement::{moved_keys, replica_counts, Ring};
pub use router::{route, RoutePlan};
pub use sim::{Cluster, ClusterCfg, ClusterStats, Node, RebalanceReport};
