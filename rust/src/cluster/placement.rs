//! Consistent-hash placement: virtual-node ring + hot-adapter promotion.
//!
//! Placement answers one question — *which nodes own adapter `name`?* —
//! and must answer it identically in every process, session, and replay,
//! because the cluster determinism contract (bitwise-identical responses
//! regardless of node count) reduces to "the same pinned request always
//! reaches a node holding the same immutable `name@v` bytes". Everything
//! here is therefore a pure function of [`crate::util::hash::fnv64`]:
//!
//! * each node contributes `vnodes` points on the u64 circle, hashed from
//!   a stable label (`"node{id}#vn{k}"`), so one physical node's load is
//!   the union of many small arcs and joins/leaves move only the arcs
//!   adjacent to the changed node's points (≈1/N of keys, the classic
//!   consistent-hashing bound, property-tested in `tests/cluster.rs`);
//! * a key's **primary** is the first point clockwise of `fnv64(key)`;
//!   its **replica set** continues clockwise collecting the first R
//!   *distinct* nodes, so replicas land on different physical nodes;
//! * Zipf-hot adapters are promoted to extra replicas by
//!   [`replica_counts`] from *observed* request counts — the router
//!   spreads a hot adapter's traffic over its widened replica set while
//!   cold adapters stay at the base replication factor.

use std::collections::BTreeMap;

use crate::util::hash::fnv64;

/// A consistent-hash ring over physical node ids.
///
/// Points are `(hash, node)` pairs sorted by hash; lookups binary-search
/// the first point at or after the key's hash (wrapping). Ties on the
/// hash value (astronomically unlikely, but determinism must not hinge
/// on luck) break by node id via the tuple sort.
#[derive(Debug, Clone)]
pub struct Ring {
    vnodes: usize,
    points: Vec<(u64, usize)>,
    nodes: Vec<usize>,
}

fn vnode_point(node: usize, k: usize) -> u64 {
    fnv64(&format!("node{node}#vn{k}"))
}

impl Ring {
    /// Ring over `nodes` with `vnodes` points each (`vnodes` is clamped
    /// to ≥ 1). Node ids need not be contiguous — the cluster keeps dead
    /// nodes' ids reserved so survivors never get renumbered.
    pub fn new(nodes: &[usize], vnodes: usize) -> Ring {
        let mut ring = Ring { vnodes: vnodes.max(1), points: Vec::new(), nodes: Vec::new() };
        for &n in nodes {
            ring.add_node(n);
        }
        ring
    }

    /// Node ids currently on the ring, ascending.
    pub fn nodes(&self) -> &[usize] {
        &self.nodes
    }

    pub fn contains(&self, node: usize) -> bool {
        self.nodes.binary_search(&node).is_ok()
    }

    /// Add a node's virtual points. No-op if already present.
    pub fn add_node(&mut self, node: usize) {
        if let Err(slot) = self.nodes.binary_search(&node) {
            self.nodes.insert(slot, node);
            self.points.extend((0..self.vnodes).map(|k| (vnode_point(node, k), node)));
            self.points.sort_unstable();
        }
    }

    /// Remove a node's virtual points. No-op if absent.
    pub fn remove_node(&mut self, node: usize) {
        if let Ok(slot) = self.nodes.binary_search(&node) {
            self.nodes.remove(slot);
            self.points.retain(|&(_, n)| n != node);
        }
    }

    /// First point clockwise of `fnv64(key)` (wrapping past u64::MAX).
    /// `None` on an empty ring.
    pub fn primary(&self, key: &str) -> Option<usize> {
        self.replicas(key, 1).first().copied()
    }

    /// The first `r` *distinct* nodes clockwise of the key's hash — the
    /// key's replica set, primary first. Returns fewer than `r` nodes
    /// when the ring has fewer than `r` nodes.
    pub fn replicas(&self, key: &str, r: usize) -> Vec<usize> {
        if self.points.is_empty() || r == 0 {
            return Vec::new();
        }
        let h = fnv64(key);
        let start = self.points.partition_point(|&(p, _)| p < h) % self.points.len();
        let want = r.min(self.nodes.len());
        let mut out = Vec::with_capacity(want);
        for i in 0..self.points.len() {
            let (_, node) = self.points[(start + i) % self.points.len()];
            if !out.contains(&node) {
                out.push(node);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

/// Per-adapter replica counts from observed request counts: every name
/// gets `base`; names whose count exceeds `hot_factor ×` the mean count
/// get `base + hot_extra` (capped later by ring size in
/// [`Ring::replicas`]). Deterministic: the counts map is ordered and the
/// threshold is pure arithmetic. Returns only the promoted names; absent
/// names implicitly have `base` replicas.
pub fn replica_counts(
    counts: &BTreeMap<String, usize>,
    base: usize,
    hot_extra: usize,
    hot_factor: f64,
) -> BTreeMap<String, usize> {
    if counts.is_empty() || hot_extra == 0 {
        return BTreeMap::new();
    }
    let mean = counts.values().sum::<usize>() as f64 / counts.len() as f64;
    let threshold = hot_factor * mean;
    counts
        .iter()
        .filter(|(_, &c)| c as f64 > threshold)
        .map(|(name, _)| (name.clone(), base + hot_extra))
        .collect()
}

/// Keys whose replica set gained owners going from `before` to `after`
/// (node join, or failed-node removal): `(key, new_owners)` per moved
/// key, where `new_owners` are the nodes in the `after` set that were
/// not in the `before` set. The rebalance layer syncs exactly these —
/// consistent hashing's point is that this list stays ≈ 1/N of all keys.
pub fn moved_keys(
    before: &Ring,
    after: &Ring,
    keys: &[String],
    r: usize,
) -> Vec<(String, Vec<usize>)> {
    let mut out = Vec::new();
    for key in keys {
        let old = before.replicas(key, r);
        let new_owners: Vec<usize> =
            after.replicas(key, r).into_iter().filter(|n| !old.contains(n)).collect();
        if !new_owners.is_empty() {
            out.push((key.clone(), new_owners));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_are_distinct_primary_first() {
        let ring = Ring::new(&[0, 1, 2, 3], 32);
        for key in ["zipf_0000", "zipf_0407", "task_rte"] {
            let reps = ring.replicas(key, 3);
            assert_eq!(reps.len(), 3);
            let mut dedup = reps.clone();
            dedup.dedup();
            assert_eq!(dedup, reps, "replica set must be distinct nodes");
            assert_eq!(reps[0], ring.primary(key).unwrap());
        }
        // More replicas than nodes: clamp, not panic.
        assert_eq!(ring.replicas("zipf_0000", 9).len(), 4);
    }

    #[test]
    fn empty_and_single_node_rings() {
        let empty = Ring::new(&[], 16);
        assert_eq!(empty.primary("x"), None);
        assert!(empty.replicas("x", 2).is_empty());
        let one = Ring::new(&[7], 16);
        assert_eq!(one.primary("x"), Some(7));
        assert_eq!(one.replicas("x", 2), vec![7]);
    }

    #[test]
    fn add_remove_roundtrip_restores_placement() {
        let base = Ring::new(&[0, 1, 2], 32);
        let mut ring = base.clone();
        ring.add_node(3);
        ring.remove_node(3);
        for i in 0..100 {
            let key = format!("k{i}");
            assert_eq!(ring.primary(&key), base.primary(&key));
        }
        assert_eq!(ring.nodes(), &[0, 1, 2]);
    }

    #[test]
    fn replica_counts_promote_only_hot_names() {
        let counts: BTreeMap<String, usize> =
            [("hot".into(), 900), ("warm".into(), 60), ("cold".into(), 40)].into();
        let plan = replica_counts(&counts, 2, 1, 2.0);
        assert_eq!(plan.get("hot"), Some(&3), "900 > 2 × mean(333) promotes");
        assert!(!plan.contains_key("warm"));
        assert!(!plan.contains_key("cold"));
        assert!(replica_counts(&counts, 2, 0, 2.0).is_empty(), "hot_extra 0 disables");
    }
}
