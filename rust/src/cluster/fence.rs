//! Per-adapter version fence: two-phase publish propagation.
//!
//! The hazard: during a publish storm, replica A may already hold `v2`
//! of an adapter while replica B still serves `v1`. If admission pinned
//! "whatever version the serving replica happens to have", two requests
//! from one client could straddle generations — and worse, the *same*
//! request would produce different bits depending on which replica the
//! router picked, breaking the cluster's replica-invariance contract.
//!
//! The fence removes the hazard by splitting publish into two phases:
//!
//! 1. **stage** — the new version is written to every replica's store
//!    ([`crate::adapter::AdapterStore::publish`] on the first replica
//!    assigns the number; [`crate::adapter::AdapterStore::install_version`]
//!    copies the identical stamped bytes to the rest). Staging is
//!    invisible to admission: the fence still pins the old version, and
//!    every replica retains the old version's immutable history file, so
//!    in-flight *and* newly admitted requests keep resolving `name@old`
//!    bitwise-identically on any replica.
//! 2. **flip** — once every replica has acknowledged the stage, the
//!    fence entry swaps to the new version in one map write. Requests
//!    admitted after the flip pin `name@new`; requests admitted before
//!    keep their `name@old` pin and still resolve it everywhere. No
//!    request ever observes a mixed generation.
//!
//! [`VersionFence::flip`] refuses to flip unless the staged replica set
//! covers the adapter's current replica assignment — a partial stage
//! (e.g. a node failing mid-publish) leaves the fence on the old version
//! rather than racing ahead of a replica that never got the bytes.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{bail, ensure, Result};

use crate::util::lock_recover;

/// The cluster's admission-visible version map plus in-flight stages.
/// Interior mutability so serving (`&Cluster`) can read pins while a
/// publisher thread stages; both maps are guarded by poison-tolerant
/// locks (a publisher panic must not wedge admission).
#[derive(Debug, Default)]
pub struct VersionFence {
    /// base name -> version admission pins right now.
    current: Mutex<BTreeMap<String, u64>>,
    /// base name -> (staged version, replica nodes that have the bytes).
    staged: Mutex<BTreeMap<String, (u64, Vec<usize>)>>,
}

impl VersionFence {
    pub fn new(init: impl IntoIterator<Item = (String, u64)>) -> VersionFence {
        VersionFence {
            current: Mutex::new(init.into_iter().collect()),
            staged: Mutex::new(BTreeMap::new()),
        }
    }

    /// Version admission pins for `base` right now (`None` for unknown
    /// adapters — the router leaves those requests unpinned).
    pub fn pinned(&self, base: &str) -> Option<u64> {
        lock_recover(&self.current).get(base).copied()
    }

    /// Snapshot of the whole pin map (one lock acquisition, so a serve
    /// call pins every request of a queue against a single generation
    /// observation).
    pub fn pin_map(&self) -> BTreeMap<String, u64> {
        lock_recover(&self.current).clone()
    }

    /// Phase 1 bookkeeping: record that `node` now holds `version` of
    /// `base`. All replicas of one in-flight publish must agree on the
    /// number (they share the first replica's stamp); a second publish
    /// of the same adapter must not start while one is staged.
    pub fn note_staged(&self, base: &str, version: u64, node: usize) -> Result<()> {
        let cur = self.pinned(base).unwrap_or(0);
        ensure!(
            version > cur,
            "stage of '{base}' v{version} is not ahead of the fence (current v{cur})"
        );
        let mut staged = lock_recover(&self.staged);
        match staged.get_mut(base) {
            None => {
                staged.insert(base.to_string(), (version, vec![node]));
            }
            Some((v, nodes)) => {
                ensure!(
                    *v == version,
                    "version fence divergence on '{base}': node {node} staged v{version} \
                     while v{} is already in flight",
                    *v
                );
                if !nodes.contains(&node) {
                    nodes.push(node);
                }
            }
        }
        Ok(())
    }

    /// In-flight stage of `base`, if any: (version, nodes holding it).
    pub fn staged(&self, base: &str) -> Option<(u64, Vec<usize>)> {
        lock_recover(&self.staged).get(base).cloned()
    }

    /// Phase 2: atomically repoint admission to the staged version.
    /// Refuses unless every node in `replicas` acknowledged the stage —
    /// a partial stage keeps serving the old generation instead of
    /// racing ahead of a replica that never got the bytes.
    pub fn flip(&self, base: &str, replicas: &[usize]) -> Result<u64> {
        let mut staged = lock_recover(&self.staged);
        let Some((version, have)) = staged.get(base).cloned() else {
            bail!("flip of '{base}' with nothing staged");
        };
        let missing: Vec<usize> = replicas.iter().copied().filter(|n| !have.contains(n)).collect();
        ensure!(
            missing.is_empty(),
            "cannot flip '{base}' to v{version}: replicas {missing:?} have not staged it"
        );
        staged.remove(base);
        drop(staged);
        lock_recover(&self.current).insert(base.to_string(), version);
        Ok(version)
    }

    /// Register a new adapter (or fast-forward after a sync) without the
    /// two-phase dance — used at cluster build where every node is
    /// populated before serving starts.
    pub fn set(&self, base: &str, version: u64) {
        lock_recover(&self.current).insert(base.to_string(), version);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_stage_cannot_flip() {
        let fence = VersionFence::new([("a".to_string(), 1)]);
        fence.note_staged("a", 2, 0).unwrap();
        let err = fence.flip("a", &[0, 1]).unwrap_err().to_string();
        assert!(err.contains("have not staged"), "got: {err}");
        assert_eq!(fence.pinned("a"), Some(1), "fence must stay on the old generation");
        fence.note_staged("a", 2, 1).unwrap();
        assert_eq!(fence.flip("a", &[0, 1]).unwrap(), 2);
        assert_eq!(fence.pinned("a"), Some(2));
        assert_eq!(fence.staged("a"), None, "flip consumes the stage");
    }

    #[test]
    fn divergent_or_stale_stage_is_rejected() {
        let fence = VersionFence::new([("a".to_string(), 3)]);
        assert!(fence.note_staged("a", 3, 0).is_err(), "stage must be ahead of the fence");
        fence.note_staged("a", 4, 0).unwrap();
        let err = fence.note_staged("a", 5, 1).unwrap_err().to_string();
        assert!(err.contains("divergence"), "got: {err}");
        assert!(fence.flip("b", &[0]).is_err(), "nothing staged for 'b'");
    }
}
