//! Cluster scale-out benchmark (`cargo bench --bench cluster`).
//!
//! Serves the zipf500 Poisson open-loop workload on simulated clusters
//! of 1 / 2 / 4 nodes (replicas 2, 4 workers per node) and reports:
//!
//! * `cluster/scaleout/zipf500_n{1,2,4}` — wall time of one full serve
//!   wave (sequential node execution: this is total simulation cost,
//!   ≈ constant across node counts since total work is constant);
//! * `cluster/scaleout/goodput_ratio_n{2,4}` — the scale-out figure of
//!   merit: goodput per second of cluster *makespan* (max per-node wall
//!   — each simulated node notionally owns a whole machine), relative
//!   to the single-node baseline. The cluster-smoke CI job gates
//!   `n4 ≥ 1.5×`; placement balance puts the expectation near `1/max
//!   node share ≈ 3×` at zipf 1.1 skew.
//!
//! Every serve wave is the same pinned request set — the response
//! digests agree across node counts (gated in CI via the `repro
//! cluster` CLI), so the rows compare identical work, not merely
//! similar work.

use fourier_peft::cluster::{Cluster, ClusterCfg};
use fourier_peft::coordinator::scheduler::{AdmissionCfg, ApplyMode, SchedCfg};
use fourier_peft::coordinator::workload::{self, OpenLoopCfg, WorkloadCfg};
use fourier_peft::util::bench::Bench;
use fourier_peft::util::median;

fn main() -> anyhow::Result<()> {
    let qb = Bench { warmup: 1, samples: 3 };
    let wl = WorkloadCfg::zipf500();
    // Sustainable Poisson load (matches `serving/open_loop/poisson_w4`):
    // the rows price routing + serving, not the shed path.
    let ol = OpenLoopCfg::poisson(40.0, 4096);
    let adm = AdmissionCfg { service_ticks: 16, queue_depth: 4096, ..AdmissionCfg::default() };
    let sched = SchedCfg { workers: 4, apply: ApplyMode::Auto, ..SchedCfg::default() };
    let arrivals = workload::gen_arrivals(&ol, workload::gen_requests(&wl)?)?;

    let mut goodput_rps = Vec::new();
    for n in [1usize, 2, 4] {
        let dir = std::env::temp_dir()
            .join(format!("fp_bench_cluster_n{n}_{}", std::process::id()));
        let cluster = Cluster::build(&dir, &wl, ClusterCfg::new(n, n.min(2)))?;
        let mut rates = Vec::new();
        qb.run(&format!("cluster/scaleout/zipf500_n{n}"), || {
            let (_, stats) = cluster.serve_open_loop(arrivals.clone(), &sched, &adm).unwrap();
            rates.push(stats.goodput_rps());
        });
        let (_, stats) = cluster.serve_open_loop(arrivals.clone(), &sched, &adm)?;
        println!(
            "{:<44} makespan {:.3}s (node-seconds {:.3})  goodput {}/{}  \
             failovers {}  promoted {}  synced {}",
            format!("cluster/scaleout/counters_n{n}"),
            stats.wall_max_seconds,
            stats.total.wall_seconds,
            stats.total.goodput,
            stats.total.offered,
            stats.failovers,
            stats.promoted.len(),
            stats.synced,
        );
        goodput_rps.push(median(&rates));
        let _ = std::fs::remove_dir_all(&dir);
    }

    let base = goodput_rps[0].max(f64::MIN_POSITIVE);
    for (i, n) in [2usize, 4].into_iter().enumerate() {
        println!(
            "{:<44} {:.2}x (goodput per makespan-second vs n1)",
            format!("cluster/scaleout/goodput_ratio_n{n}"),
            goodput_rps[i + 1] / base,
        );
    }
    Ok(())
}
