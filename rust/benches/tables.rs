//! Table benches (`cargo bench --bench tables`): regenerates every paper
//! *table* end-to-end in quick mode and times each driver. The printed
//! tables are the reproduction artifacts; the timings document the cost of
//! regenerating them on this machine.
//!
//! Full-fidelity runs (more seeds/steps) are `repro table N` without
//! `--quick` — see EXPERIMENTS.md for the recorded full runs.

use fourier_peft::coordinator::experiments;
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::util::cli::Args;
use fourier_peft::util::timed;

fn main() -> anyhow::Result<()> {
    // honor `cargo bench -- --quick-steps 30`
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let mut args = Args::parse(argv);
    args.flags.entry("quick".into()).or_insert_with(|| "true".into());
    args.flags.entry("steps".into()).or_insert_with(|| "25".into());
    args.flags.entry("eval-count".into()).or_insert_with(|| "64".into());
    args.flags.entry("seeds".into()).or_insert_with(|| "1".into());

    let trainer = Trainer::open_default()?;
    for id in ["table1", "table2", "table3", "table4", "table5", "table6"] {
        let (res, secs) = timed(|| experiments::run(&trainer, id, &args));
        match res {
            Ok(reports) => println!(
                "bench {id:<8} ok   {:>8.1}s   ({} report(s))",
                secs,
                reports.len()
            ),
            Err(e) => println!("bench {id:<8} FAIL {:>8.1}s   {e:#}", secs),
        }
    }
    Ok(())
}
