//! Micro-benchmarks (`cargo bench --bench micro`): the hot paths of the
//! serving and reconstruction stack.
//!
//! * ΔW reconstruction: rust trig-IDFT vs rust FFT-IDFT vs the AOT XLA
//!   (Pallas-kernel) artifact, across n — locating the algorithmic
//!   crossover documented in EXPERIMENTS.md §Perf.
//! * adapter swap cost: FourierFT vs LoRA vs dense-delta checkpoint load.
//! * one fused train step / eval step on each model family.
//! * adapter file save/load throughput.

use fourier_peft::adapter::format::{AdapterFile, AdapterKind};
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::fourier::{idft2_real_sparse, idft2_real_sparse_fft, sample_entries, EntryBias};
use fourier_peft::runtime::to_literal;
use fourier_peft::tensor::{rng::Rng, Tensor};
use fourier_peft::util::bench::Bench;
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    let mut rng = Rng::new(0xBE
        ^ 0x2C);

    // --- ΔW reconstruction across n (d = 128, the enc_base shape) --------
    let d = 128;
    for n in [16, 64, 256, 1024] {
        let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 2024);
        let c = rng.normal_vec(n, 1.0);
        b.run(&format!("reconstruct/trig_idft/d128_n{n}"), || {
            idft2_real_sparse((&rows, &cols), &c, d, d, 8.0)
        });
        b.run(&format!("reconstruct/fft_idft/d128_n{n}"), || {
            idft2_real_sparse_fft((&rows, &cols), &c, d, d, 8.0)
        });
    }

    // --- XLA (Pallas kernel) reconstruction via the delta artifact -------
    let trainer = Trainer::open_default()?;
    for n in [64usize, 1024] {
        if let Ok(hlo) = trainer.registry.delta_hlo(d, n) {
            let exe = trainer.client.load_hlo(&hlo)?;
            let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 2024);
            let mut e = rows.clone();
            e.extend(&cols);
            let args = [
                to_literal(&Tensor::i32(&[2, n], e))?,
                to_literal(&Tensor::f32(&[n], rng.normal_vec(n, 1.0)))?,
                to_literal(&Tensor::scalar(8.0))?,
            ];
            b.run(&format!("reconstruct/xla_pallas/d128_n{n}"), || {
                exe.execute::<xla::Literal>(&args).unwrap()
            });
        }
    }

    // --- adapter checkpoint save/load ------------------------------------
    let dir = std::env::temp_dir().join("fp_bench_store");
    let _ = std::fs::create_dir_all(&dir);
    let make = |kind: AdapterKind, tensors: Vec<(String, Tensor)>| AdapterFile {
        kind,
        seed: 2024,
        alpha: 8.0,
        meta: vec![],
        tensors,
    };
    let fft_file = make(
        AdapterKind::FourierFt,
        (0..8).map(|i| (format!("spec.blk{i}.c"), Tensor::zeros(&[64]))).collect(),
    );
    let lora_file = make(
        AdapterKind::Lora,
        (0..8)
            .flat_map(|i| [
                (format!("lora.blk{i}.a"), Tensor::zeros(&[8, 128])),
                (format!("lora.blk{i}.b"), Tensor::zeros(&[128, 8])),
            ])
            .collect(),
    );
    let dense_file = make(
        AdapterKind::DenseDelta,
        (0..8).map(|i| (format!("delta.blk{i}"), Tensor::zeros(&[128, 128]))).collect(),
    );
    for (name, file) in [("fourierft", &fft_file), ("lora", &lora_file), ("dense", &dense_file)] {
        let path = dir.join(format!("{name}.adapter"));
        b.run(&format!("adapter_io/save/{name}"), || file.save(&path).unwrap());
        b.run(&format!("adapter_io/load/{name}"), || AdapterFile::load(&path).unwrap());
        println!("{:<44} size: {}", format!("adapter_io/bytes/{name}"),
                 fourier_peft::util::fmt_bytes(file.byte_size()));
    }

    // --- fused step latency per model family ------------------------------
    for artifact in ["mlp__fourierft_n128__ce", "enc_base__fourierft_n64__ce",
                     "enc_base__lora_r8__ce", "enc_base__ff__ce"] {
        let exe = trainer.executable(artifact)?;
        let meta = exe.meta.clone();
        let (statics, _) = trainer.make_statics(&meta, 2024, EntryBias::None)?;
        let base = trainer.base_for(&meta)?;
        let mut state = exe.init_state(0, base, statics)?;
        let batch: HashMap<String, Tensor> = if meta.model.kind == "mlp" {
            fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                meta.model.batch, 0.35, 1))
        } else {
            fourier_peft::data::collate_text(
                &fourier_peft::data::glue::GlueTask::Rte.split("train", meta.model.batch, 1),
                meta.model.seqlen,
            )
        };
        b.run(&format!("step/train/{artifact}"), || {
            exe.step(
                &mut state,
                fourier_peft::runtime::exec::StepScalars {
                    step: 1.0, lr: 1e-3, lr_head: 1e-3, wd: 0.0, scaling: 8.0,
                },
                &batch,
            )
            .unwrap()
        });
        b.run(&format!("step/eval/{artifact}"), || {
            exe.eval(&mut state, 8.0, &batch).unwrap()
        });
    }

    // --- end-to-end short fine-tune (the trainer loop itself) ------------
    let quick = Bench::quick();
    quick.run("trainer/finetune_20steps/mlp_fourierft", || {
        let mut cfg = FinetuneCfg::new("mlp__fourierft_n128__ce");
        cfg.steps = 20;
        cfg.lr = 0.05;
        cfg.scaling = 64.0;
        trainer
            .finetune(
                &cfg,
                |step, _| {
                    fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                        64, 0.35, step as u64,
                    ))
                },
                None,
            )
            .unwrap()
    });
    Ok(())
}
