//! Micro-benchmarks (`cargo bench --bench micro`): the hot paths of the
//! serving and reconstruction stack.
//!
//! * ΔW reconstruction: rust trig-IDFT vs rust FFT-IDFT vs the GEMM plan
//!   (cold build and plan-cached warm call) vs the AOT XLA (Pallas-kernel)
//!   artifact, across n — locating the algorithmic crossovers documented
//!   in EXPERIMENTS.md §Perf.
//! * adapter swap cost: FourierFT vs LoRA vs dense-delta checkpoint load,
//!   plus the serving swap-cache stack cold vs warm
//!   (`serving/swap_cached/*`).
//! * the micro-batching scheduler vs sequential serve on the 500-adapter
//!   Zipf workload (`serving/sched_seq/*`, `serving/sched_par/*` at
//!   1/2/4/8 workers, latency percentiles, warm-swap counters, and a
//!   4-worker-vs-sequential speedup summary).
//! * one fused train step / eval step on each model family (XLA builds).
//! * adapter file save/load throughput.
//!
//! Sections that need compiled HLO artifacts are skipped (with a notice)
//! when the registry or the `xla-runtime` feature is unavailable, so the
//! pure-Rust rows always run.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::method::{self, MethodHp, SiteSpec};
use fourier_peft::adapter::store::AdapterStore;
use fourier_peft::coordinator::serving::SwapCache;
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::fourier::{
    idft2_real_sparse, idft2_real_sparse_fft, plan, sample_entries, EntryBias, ReconstructPlan,
};
use fourier_peft::runtime::{to_literal, xla, StepEngine};
use fourier_peft::tensor::{rng::Rng, Tensor};
use fourier_peft::util::bench::{fmt_time, Bench};
use std::collections::{BTreeMap, HashMap};

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    let mut rng = Rng::new(0xBE ^ 0x2C);

    // --- ΔW reconstruction across n (d = 128, the enc_base shape) --------
    let d = 128;
    let mut trig_at_n1024 = f64::NAN;
    let mut gemm_at_n1024 = f64::NAN;
    for n in [16, 64, 256, 1024] {
        let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 2024).unwrap();
        let c = rng.normal_vec(n, 1.0);
        let trig = b.run(&format!("reconstruct/trig_idft/d128_n{n}"), || {
            idft2_real_sparse((&rows, &cols), &c, d, d, 8.0).unwrap()
        });
        b.run(&format!("reconstruct/fft_idft/d128_n{n}"), || {
            idft2_real_sparse_fft((&rows, &cols), &c, d, d, 8.0).unwrap()
        });
        // cold: twiddle-table build + GEMM every call
        b.run(&format!("reconstruct/gemm_idft_cold/d128_n{n}"), || {
            ReconstructPlan::new((&rows, &cols), d, d).unwrap().reconstruct(&c, 8.0).unwrap()
        });
        // warm (the serving steady state): plan from the process cache
        let p = plan::global().get((&rows, &cols), d, d)?;
        let gemm = b.run(&format!("reconstruct/gemm_idft/d128_n{n}"), || {
            p.reconstruct(&c, 8.0).unwrap()
        });
        if n == 1024 {
            trig_at_n1024 = trig;
            gemm_at_n1024 = gemm;
        }
    }

    // --- factored (no-materialize) apply vs dense reconstruct+apply ------
    // Dense applies x·ΔW after materializing ΔW (d² MACs/row + the
    // reconstruct); factored runs the same product as two stacked GEMMs
    // straight from the plan (2n(d1+d2) MACs/row, no d² intermediate).
    // The crossover in n documented in EXPERIMENTS.md §Perf comes from
    // these rows: factored wins iff 2n(d1+d2) < d1·d2.
    for (dd, batch) in [(128usize, 8usize), (768, 8), (768, 32)] {
        for n in [16usize, 128] {
            let (rows, cols) = sample_entries(dd, dd, n, EntryBias::None, 2024).unwrap();
            let c = rng.normal_vec(n, 1.0);
            let p = plan::global().get((&rows, &cols), dd, dd)?;
            let x = rng.normal_vec(batch * dd, 1.0);
            b.run(&format!("reconstruct/dense_apply/d{dd}_n{n}_b{batch}"), || {
                let dw = p.reconstruct(&c, 8.0).unwrap();
                fourier_peft::tensor::par::matmul_f32(&x, &dw, batch, dd, dd)
            });
            b.run(&format!("reconstruct/factored/d{dd}_n{n}_b{batch}"), || {
                p.apply(&x, batch, &c, 8.0).unwrap()
            });
        }
    }
    println!(
        "{:<44} {:.1}x  (trig {} vs gemm {})",
        "reconstruct/speedup_gemm_vs_trig/d128_n1024",
        trig_at_n1024 / gemm_at_n1024,
        fmt_time(trig_at_n1024),
        fmt_time(gemm_at_n1024),
    );

    // --- the two new registry methods, through the trait dispatch ---------
    // (`reconstruct/loca/*` is the iDCT-at-learned-locations GEMM,
    //  `reconstruct/circulant/*` the O(d²) circulant×diagonal gather.)
    {
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let mut mrng = Rng::new(0x10CA);
        for n in [16usize, 64, 256, 1024] {
            let hp = MethodHp { n, rank: 8, init_std: 1.0 };
            let a =
                method::init_adapter("loca", &mut mrng, &[site.clone()], &hp, 2024, 8.0, vec![])?;
            b.run(&format!("reconstruct/loca/d128_n{n}"), || {
                method::site_deltas(&a).unwrap()
            });
        }
        let a = method::init_adapter(
            "circulant", &mut mrng, &[site], &MethodHp::default(), 2024, 8.0, vec![],
        )?;
        b.run("reconstruct/circulant/d128", || method::site_deltas(&a).unwrap());
    }

    // --- serving swap-cache stack: cold vs warm ΔW swap -------------------
    {
        let dir = std::env::temp_dir().join(format!("fp_bench_swap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = AdapterStore::open(&dir)?;
        let n = 256;
        let sites = 8;
        let site_dims: BTreeMap<String, (usize, usize)> =
            (0..sites).map(|i| (format!("blk{i}.attn.wq.w"), (d, d))).collect();
        let file = AdapterFile::from_named(
            "fourierft",
            2024,
            8.0,
            vec![("n".into(), n.to_string())],
            (0..sites)
                .map(|i| (format!("spec.blk{i}.attn.wq.w.c"), {
                    Tensor::f32(&[n], rng.normal_vec(n, 1.0))
                }))
                .collect(),
            |site| site_dims.get(site).copied(),
        )?;
        store.save("hot_adapter", &file)?;

        let mut cold = SwapCache::new(site_dims.clone());
        b.run("serving/swap_cold/fourierft_8x128", || {
            // full cold path: decode-cache bypassed + ΔW rebuilt every time
            cold.invalidate("hot_adapter");
            store.invalidate("hot_adapter");
            plan::global().clear();
            cold.deltas(&mut store, "hot_adapter").unwrap()
        });
        let mut warm = SwapCache::new(site_dims);
        warm.deltas(&mut store, "hot_adapter")?; // populate
        let disk_before_warm = store.disk_reads();
        b.run("serving/swap_cached/fourierft_8x128", || {
            warm.deltas(&mut store, "hot_adapter").unwrap()
        });
        println!(
            "{:<44} hits {} builds {} disk_reads {}",
            "serving/swap_cached/counters",
            warm.stats.delta_hits,
            warm.stats.delta_builds,
            store.disk_reads() - disk_before_warm,
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // --- micro-batching scheduler vs sequential serve (500-adapter Zipf) --
    {
        use fourier_peft::adapter::store::SharedAdapterStore;
        use fourier_peft::coordinator::scheduler::{
            self, serve_open_loop_host, AdmissionCfg, ApplyMode, SchedCfg,
        };
        use fourier_peft::coordinator::serving::SharedSwap;
        use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg, WorkloadCfg};

        let dir = std::env::temp_dir().join(format!("fp_bench_sched_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = WorkloadCfg::zipf500();
        let store = SharedAdapterStore::with_shards(&dir, 8, 128)?;
        workload::populate_store(&store, &wl)?;
        let swap = SharedSwap::with_shards(workload::site_dims(&wl), 8, 128);
        let queue = workload::gen_requests(&wl).unwrap();
        let sched = |workers: usize, apply: ApplyMode| SchedCfg {
            workers,
            max_batch: 32,
            max_wait_ticks: 256,
            queue_cap: 1024,
            apply,
        };

        // Warm the cache stack once so every row below measures the
        // serving steady state (cold-build cost is `serving/swap_cold/*`'s
        // story; warm-swap counters below prove the rows stay warm).
        scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &sched(2, ApplyMode::Dense))?;
        scheduler::serve_scheduled_host(
            &swap,
            &store,
            queue.clone(),
            &sched(2, ApplyMode::Factored),
        )?;

        let qb = Bench::quick();
        let seq_t = qb.run("serving/sched_seq/zipf500", || {
            scheduler::serve_sequential_host(&swap, &store, queue.clone(), ApplyMode::Dense)
                .unwrap()
        });
        let mut par4_t = f64::NAN;
        for workers in [1usize, 2, 4, 8] {
            let cfg = sched(workers, ApplyMode::Dense);
            let t = qb.run(&format!("serving/sched_par/zipf500_w{workers}"), || {
                scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &cfg).unwrap()
            });
            if workers == 4 {
                par4_t = t;
            }
        }
        println!(
            "{:<44} {:.1}x  (seq {} vs 4 workers {})",
            "serving/sched_speedup_4w_vs_seq/zipf500",
            seq_t / par4_t,
            fmt_time(seq_t),
            fmt_time(par4_t),
        );

        // Factored + auto dispatch on the same workload. At zipf500's
        // geometry (d=64, n=64) the factored apply costs 2n(d1+d2) = 4×
        // the dense MACs, so auto stays dense — these rows document the
        // cost model's *negative* verdict; the n=128/d=768 block below
        // shows the positive one.
        for (apply, tag) in
            [(ApplyMode::Factored, "sched_factored"), (ApplyMode::Auto, "sched_auto")]
        {
            let cfg = sched(4, apply);
            qb.run(&format!("serving/{tag}/zipf500_w4"), || {
                scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &cfg).unwrap()
            });
        }

        // Latency percentiles + warm-swap counters from one instrumented
        // run per path: the cache stack must short-circuit all disk and
        // IDFT work while the scheduler parallelizes execution.
        let cfg4 = sched(4, ApplyMode::Dense);
        let (_, par_stats) = scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &cfg4)?;
        let (_, seq_stats) =
            scheduler::serve_sequential_host(&swap, &store, queue.clone(), ApplyMode::Dense)?;
        qb.report_percentiles("serving/sched_seq/latency", &seq_stats.latencies);
        qb.report_percentiles("serving/sched_par/latency_w4", &par_stats.latencies);
        let sw = swap.stats();
        println!(
            "{:<44} swaps {} warm {} disk_reads {} delta_hits {} delta_builds {}",
            "serving/sched_par/warm_counters",
            par_stats.swaps,
            par_stats.warm_swaps,
            par_stats.disk_reads,
            sw.delta_hits,
            sw.delta_builds,
        );

        // Open-loop rows over the same warmed Zipf stack. `poisson_w4`
        // offers a sustainable load (no shedding — the row prices the
        // virtual-clock router, SLO bookkeeping, and admission pass);
        // `burst_overload_w4` slams a 16x burst into a shallow queue so
        // the shed path itself is on the measured path.
        let adm_ok =
            AdmissionCfg { service_ticks: 16, queue_depth: 4096, ..AdmissionCfg::default() };
        let poisson = workload::gen_arrivals(&OpenLoopCfg::poisson(40.0, 4096), queue.clone())?;
        qb.run("serving/open_loop/poisson_w4", || {
            serve_open_loop_host(&swap, &store, poisson.clone(), &cfg4, &adm_ok).unwrap()
        });
        let adm_tight =
            AdmissionCfg { service_ticks: 16, queue_depth: 32, ..AdmissionCfg::default() };
        let burst = workload::gen_arrivals(
            &OpenLoopCfg {
                kind: ArrivalKind::Burst,
                burst_factor: 16.0,
                ..OpenLoopCfg::poisson(200.0, 256)
            },
            queue.clone(),
        )?;
        qb.run("serving/open_loop/burst_overload_w4", || {
            serve_open_loop_host(&swap, &store, burst.clone(), &cfg4, &adm_tight).unwrap()
        });
        let (_, ol) = serve_open_loop_host(&swap, &store, burst.clone(), &cfg4, &adm_tight)?;
        println!(
            "{:<44} offered {} shed {} ({:.1}%) goodput {} ({:.0} req/s)",
            "serving/open_loop/burst_overload_counters",
            ol.offered,
            ol.shed,
            100.0 * ol.shed_rate(),
            ol.goodput,
            ol.goodput_rps(),
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // --- factored serving at spectral-friendly geometry (n=128, d=768) ----
    // The paper-scale shape: RoBERTa-ish d=768 weights adapted by n=128
    // spectral coefficients. Factored apply is 2n(d1+d2)/d1·d2 ≈ 2/3 of
    // the dense MACs *and* skips the per-adapter d² ΔW residency, so the
    // warm per-request cost and the byte counters both drop. Adapter
    // count is reduced to 24 (dense ΔW is 2.25MB per site — 500 adapters
    // of comparator would need GBs).
    {
        use fourier_peft::adapter::store::SharedAdapterStore;
        use fourier_peft::coordinator::scheduler::{self, ApplyMode, SchedCfg};
        use fourier_peft::coordinator::serving::SharedSwap;
        use fourier_peft::coordinator::workload::{self, WorkloadCfg};

        let dir = std::env::temp_dir().join(format!("fp_bench_fact_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = WorkloadCfg {
            adapters: 24,
            requests: 192,
            dim: 768,
            sites: 1,
            n_coeffs: 128,
            batch: 8,
            method: "fourierft".into(),
            ..WorkloadCfg::zipf500()
        };
        let store = SharedAdapterStore::with_shards(&dir, 8, 64)?;
        workload::populate_store(&store, &wl)?;
        let queue = workload::gen_requests(&wl).unwrap();
        let qb = Bench::quick();
        let sched = |apply: ApplyMode| SchedCfg {
            workers: 4,
            max_batch: 32,
            max_wait_ticks: 256,
            queue_cap: 1024,
            apply,
        };

        let mut times = [f64::NAN; 2];
        for (i, (apply, tag)) in
            [(ApplyMode::Dense, "sched_par"), (ApplyMode::Factored, "sched_factored")]
                .into_iter()
                .enumerate()
        {
            // Separate swap per mode so each row's residency is its own.
            let swap = SharedSwap::with_shards(workload::site_dims(&wl), 8, 64);
            let cfg = sched(apply);
            scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &cfg)?; // warm
            times[i] = qb.run(&format!("serving/{tag}/n128_d768_w4"), || {
                scheduler::serve_scheduled_host(&swap, &store, queue.clone(), &cfg).unwrap()
            });
            let sw = swap.stats();
            println!(
                "{:<44} delta {} factors {} peak {}",
                format!("serving/{tag}/residency_n128_d768"),
                fourier_peft::util::fmt_bytes(sw.delta_bytes as usize),
                fourier_peft::util::fmt_bytes(sw.factor_bytes as usize),
                fourier_peft::util::fmt_bytes(sw.peak_bytes as usize),
            );
        }
        println!(
            "{:<44} {:.1}x  (dense {} vs factored {})",
            "serving/factored_speedup_vs_dense/n128_d768",
            times[0] / times[1],
            fmt_time(times[0]),
            fmt_time(times[1]),
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // --- adapter checkpoint save/load ------------------------------------
    let dir = std::env::temp_dir().join("fp_bench_store");
    let _ = std::fs::create_dir_all(&dir);
    let make = |method: &str, tensors: Vec<(String, Tensor)>| {
        AdapterFile::from_named(method, 2024, 8.0, vec![], tensors, |_| Some((128, 128)))
            .expect("builtin method")
    };
    let fft_file = make(
        "fourierft",
        (0..8).map(|i| (format!("spec.blk{i}.c"), Tensor::zeros(&[64]))).collect(),
    );
    let lora_file = make(
        "lora",
        (0..8)
            .flat_map(|i| [
                (format!("lora.blk{i}.a"), Tensor::zeros(&[8, 128])),
                (format!("lora.blk{i}.b"), Tensor::zeros(&[128, 8])),
            ])
            .collect(),
    );
    let dense_file = make(
        "dense",
        (0..8).map(|i| (format!("delta.blk{i}"), Tensor::zeros(&[128, 128]))).collect(),
    );
    for (name, file) in [("fourierft", &fft_file), ("lora", &lora_file), ("dense", &dense_file)] {
        let path = dir.join(format!("{name}.adapter"));
        b.run(&format!("adapter_io/save/{name}"), || file.save(&path).unwrap());
        b.run(&format!("adapter_io/load/{name}"), || AdapterFile::load(&path).unwrap());
        println!("{:<44} size: {}", format!("adapter_io/bytes/{name}"),
                 fourier_peft::util::fmt_bytes(file.byte_size()));
    }

    // --- engine-backed sections -------------------------------------------
    // The default trainer is the pure-host engine, so the training-step
    // rows below (train/host_step/*) run in every build; the Pallas
    // reconstruction rows still need artifacts + xla-runtime and skip
    // gracefully without them.
    let trainer = match Trainer::open_default() {
        Ok(t) => t,
        Err(e) => {
            println!("skipping engine-backed benches (trainer unavailable: {e:#})");
            return Ok(());
        }
    };

    // XLA (Pallas kernel) reconstruction via the delta artifact
    if let Some(reg) = &trainer.registry {
        for n in [64usize, 1024] {
            if let Ok(hlo) = reg.delta_hlo(d, n) {
                if let Ok(exe) = trainer.client.load_hlo(&hlo) {
                    let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 2024).unwrap();
                    let mut e = rows.clone();
                    e.extend(&cols);
                    let args = [
                        to_literal(&Tensor::i32(&[2, n], e))?,
                        to_literal(&Tensor::f32(&[n], rng.normal_vec(n, 1.0)))?,
                        to_literal(&Tensor::scalar(8.0))?,
                    ];
                    b.run(&format!("reconstruct/xla_pallas/d128_n{n}"), || {
                        exe.execute::<xla::Literal>(&args).unwrap()
                    });
                }
            }
        }
    }

    // --- fused step latency per model family (train/host_step/* rows
    // track the host training trajectory in BENCH_*.json) -----------------
    let engine_id = trainer.engine_kind.id();
    for artifact in ["mlp__fourierft_n128__ce", "enc_base__fourierft_n64__ce",
                     "enc_base__lora_r8__ce", "enc_base__ff__ce"] {
        let exe = match trainer.engine(artifact) {
            Ok(e) => e,
            Err(e) => {
                println!("skipping step benches for {artifact}: {e:#}");
                continue;
            }
        };
        let meta = exe.meta().clone();
        let (statics, _) = trainer.make_statics(&meta, 2024, EntryBias::None)?;
        // Seed-0 random base: step latency is shape-dependent only, and a
        // bench must not trigger a multi-minute pretraining run.
        let base = fourier_peft::runtime::host::zoo::init_base_for(&meta, 0)?;
        let mut state = exe.init_state(0, base, statics)?;
        let batch: HashMap<String, Tensor> = if meta.model.kind == "mlp" {
            fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                meta.model.batch, 0.35, 1))
        } else {
            fourier_peft::data::collate_text(
                &fourier_peft::data::glue::GlueTask::Rte.split("train", meta.model.batch, 1),
                meta.model.seqlen,
            )
        };
        let mut step_no = 0u32;
        b.run(&format!("train/{engine_id}_step/{artifact}"), || {
            step_no += 1;
            exe.step(
                &mut state,
                fourier_peft::runtime::StepScalars {
                    step: step_no as f32, lr: 1e-3, lr_head: 1e-3, wd: 0.0, scaling: 8.0,
                },
                &batch,
            )
            .unwrap()
        });
        b.run(&format!("eval/{engine_id}_step/{artifact}"), || {
            exe.eval(&mut state, 8.0, &batch).unwrap()
        });
    }

    // --- end-to-end short fine-tune (the trainer loop itself) ------------
    let quick = Bench::quick();
    quick.run("trainer/finetune_20steps/mlp_fourierft", || {
        let mut cfg = FinetuneCfg::new("mlp__fourierft_n128__ce");
        cfg.steps = 20;
        cfg.lr = 0.05;
        cfg.scaling = 64.0;
        trainer
            .finetune(
                &cfg,
                |step, _| {
                    fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                        64, 0.35, step as u64,
                    ))
                },
                None,
            )
            .unwrap()
    });
    Ok(())
}
