//! Figure benches (`cargo bench --bench figures`): regenerates every paper
//! *figure*'s data series in quick mode and times each driver (Figure 1 is
//! the summary scatter assembled from tables 4/5 reports, so it is covered
//! by `cargo bench --bench tables`; Figure 2 is a schematic).

use fourier_peft::coordinator::experiments;
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::util::cli::Args;
use fourier_peft::util::timed;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let mut args = Args::parse(argv);
    args.flags.entry("quick".into()).or_insert_with(|| "true".into());
    args.flags.entry("steps".into()).or_insert_with(|| "25".into());
    args.flags.entry("eval-count".into()).or_insert_with(|| "64".into());
    args.flags.entry("seeds".into()).or_insert_with(|| "1".into());

    let trainer = Trainer::open_default()?;
    for id in ["figure3", "figure4", "figure5", "figure6", "figure7"] {
        let (res, secs) = timed(|| experiments::run(&trainer, id, &args));
        match res {
            Ok(reports) => println!(
                "bench {id:<8} ok   {:>8.1}s   ({} report(s))",
                secs,
                reports.len()
            ),
            Err(e) => println!("bench {id:<8} FAIL {:>8.1}s   {e:#}", secs),
        }
    }
    Ok(())
}
