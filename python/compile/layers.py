"""Layer-2 building blocks: parameter init, PEFT weight deltas, and the
transformer / MLP forward passes.

Parameters are *flat* ``OrderedDict[str, jnp.ndarray]`` keyed by dotted
paths ("blk0.attn.wq", ...). The same layout is mirrored by the rust
runtime via the artifact meta JSON, so keeping it flat (no pytrees) makes
the ABI explicit.

Every method is expressed as "frozen base + delta":

    W_eff = base[k] + delta_k(adapt, statics)

For ``ff`` the delta is a dense tensor initialized to zero — since Adam is
translation-invariant this is trajectory-identical to training the weight
itself, and it lets one rust code path ("merge deltas into base") serve
every method, including pretraining.
"""

from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp

from .configs import MethodCfg, ModelCfg
from .kernels.fourier import spectral_to_delta

Params = "OrderedDict[str, jnp.ndarray]"

ADAPTED_SITES = ("attn.wq", "attn.wv")  # paper: query & value only


# ---------------------------------------------------------------------------
# FourierFT delta with custom VJP (Pallas forward, analytic trig adjoint)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _spectral_delta_fn(d1: int, d2: int):
    """Differentiable Delta_W = alpha * Re(IDFT2(ToDense(E, c))).

    Forward runs the L1 Pallas kernel; backward is the analytic adjoint

        dL/dc_l = alpha/(d1 d2) * [Cu^T G Cv - Su^T G Sv]_ll
                = alpha/(d1 d2) * [((G @ Cv) * Cu).sum(0) - ((G @ Sv) * Su).sum(0)]

    i.e. the same rank-n trig contraction transposed — two [d1,d2]x[d2,n]
    matmuls, MXU-friendly like the forward.
    """

    @jax.custom_vjp
    def f(entries, coeffs, alpha):
        return spectral_to_delta(entries, coeffs, alpha, d1=d1, d2=d2)

    def fwd(entries, coeffs, alpha):
        return f(entries, coeffs, alpha), (entries, alpha)

    def bwd(res, g):
        entries, alpha = res
        j = entries[0].astype(jnp.float32)
        k = entries[1].astype(jnp.float32)
        p = jnp.arange(d1, dtype=jnp.float32)[:, None]
        q = jnp.arange(d2, dtype=jnp.float32)[:, None]
        tu = 2.0 * jnp.pi / d1 * p * j[None, :]  # [d1, n]
        tv = 2.0 * jnp.pi / d2 * q * k[None, :]  # [d2, n]
        gc = ((g @ jnp.cos(tv)) * jnp.cos(tu)).sum(0) - (
            (g @ jnp.sin(tv)) * jnp.sin(tu)
        ).sum(0)
        gc = gc * (alpha / (d1 * d2))
        zero_e = jnp.zeros(entries.shape, dtype=jax.dtypes.float0)
        zero_a = jnp.zeros((), dtype=jnp.float32)
        return zero_e, gc.astype(jnp.float32), zero_a

    f.defvjp(fwd, bwd)
    return f


def fourier_delta(entries, coeffs, alpha, d1: int, d2: int):
    return _spectral_delta_fn(d1, d2)(entries, coeffs, alpha)


# ---------------------------------------------------------------------------
# Base parameter init
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in: int, shape) -> jnp.ndarray:
    return jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)


def init_base(cfg: ModelCfg, key) -> "OrderedDict[str, jnp.ndarray]":
    """Initialize the frozen backbone (no task head — heads live in the
    adapt tree since they are always trainable)."""
    p = OrderedDict()
    keys = iter(jax.random.split(key, 1024))

    def dense(name, din, dout, bias=True):
        p[f"{name}.w"] = _dense_init(next(keys), din, (din, dout))
        if bias:
            p[f"{name}.b"] = jnp.zeros((dout,), jnp.float32)

    def ln(name):
        p[f"{name}.g"] = jnp.ones((cfg.d,), jnp.float32)
        p[f"{name}.b"] = jnp.zeros((cfg.d,), jnp.float32)

    if cfg.kind == "mlp":
        # Fig. 7: 2 -> hidden -> hidden -> classes; the adapted site is the
        # hidden x hidden matrix, exactly as in the paper's appendix C.2.
        # The head lives in the (freezable) base so the _fh variants can
        # reproduce the paper's "only the hidden layer trains" protocol.
        p["w1.w"] = _dense_init(next(keys), 2, (2, cfg.hidden))
        p["w1.b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        p["w2.w"] = _dense_init(next(keys), cfg.hidden, (cfg.hidden, cfg.hidden))
        p["w2.b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        p["head.w"] = _dense_init(next(keys), cfg.hidden, (cfg.hidden, cfg.classes))
        p["head.b"] = jnp.zeros((cfg.classes,), jnp.float32)
        return p

    if cfg.kind == "denoiser":
        # DreamBooth-sim (Table 13): flat-pixel denoiser 768 -> h -> h -> 768
        # with the h x h core as the adapted site (mirrors adapting the
        # diffusion UNet's attention weights).
        pix = cfg.img * cfg.img * cfg.channels
        p["fc_in.w"] = _dense_init(next(keys), pix, (pix, cfg.hidden))
        p["fc_in.b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        p["w2.w"] = _dense_init(next(keys), cfg.hidden, (cfg.hidden, cfg.hidden))
        p["w2.b"] = jnp.zeros((cfg.hidden,), jnp.float32)
        p["fc_out.w"] = _dense_init(next(keys), cfg.hidden, (cfg.hidden, pix))
        p["fc_out.b"] = jnp.zeros((pix,), jnp.float32)
        return p

    if cfg.kind in ("encoder", "decoder"):
        p["tok_emb"] = jax.random.normal(next(keys), (cfg.vocab, cfg.d)) * 0.02
        p["pos_emb"] = jax.random.normal(next(keys), (cfg.tokens, cfg.d)) * 0.02
    elif cfg.kind == "vit":
        pdim = cfg.patch * cfg.patch * cfg.channels
        dense("patch", pdim, cfg.d)
        p["cls_tok"] = jax.random.normal(next(keys), (1, cfg.d)) * 0.02
        p["pos_emb"] = jax.random.normal(next(keys), (cfg.tokens, cfg.d)) * 0.02

    for i in range(cfg.layers):
        b = f"blk{i}"
        ln(f"{b}.ln1")
        for w in ("wq", "wk", "wv", "wo"):
            dense(f"{b}.attn.{w}", cfg.d, cfg.d)
        ln(f"{b}.ln2")
        dense(f"{b}.mlp.fc1", cfg.d, cfg.dff)
        dense(f"{b}.mlp.fc2", cfg.dff, cfg.d)
    ln("ln_f")
    return p


# ---------------------------------------------------------------------------
# Adapt (trainable) parameter init
# ---------------------------------------------------------------------------


def adapted_weight_keys(cfg: ModelCfg) -> list[str]:
    """Base keys whose weight gets a LoRA / FourierFT / basis delta."""
    if cfg.kind in ("mlp", "denoiser"):
        return ["w2.w"]
    return [f"blk{i}.{s}.w" for i in range(cfg.layers) for s in ADAPTED_SITES]


def head_shapes(cfg: ModelCfg, loss: str) -> "OrderedDict[str, tuple]":
    h = OrderedDict()
    if cfg.kind == "denoiser":
        return h  # no task head: the output projection stays frozen
    if cfg.kind == "mlp":
        # mlp heads are deltas on the base head (freezable, Fig. 7)
        h["delta.head.w"] = (cfg.hidden, cfg.classes)
        h["delta.head.b"] = (cfg.classes,)
    elif cfg.kind == "decoder" or loss == "mlm":
        # decoder LM head, or encoder masked-token pretraining head
        h["head.w"] = (cfg.d, cfg.vocab)
        h["head.b"] = (cfg.vocab,)
    else:
        out = 1 if loss == "mse" else cfg.classes
        h["head.w"] = (cfg.d, out)
        h["head.b"] = (out,)
    return h


def init_adapt(cfg: ModelCfg, method: MethodCfg, loss: str, key):
    """Trainable parameters: task head + method-specific deltas.

    Zero-initialized deltas guarantee the fine-tune starts exactly at the
    pretrained function (LoRA achieves this with B=0; FourierFT with c=0 —
    the paper's Gaussian c-init is available for its ablation but zero-init
    matches the peft library default and keeps eval@step0 == pretrained).
    """
    p = OrderedDict()
    keys = iter(jax.random.split(key, 4096))
    sites = adapted_weight_keys(cfg)

    if method.name == "ff":
        for k, v in init_base(cfg, next(keys)).items():
            if k.startswith("head.") and not method.head:
                continue  # frozen-head FF (Fig. 7 protocol)
            p[f"delta.{k}"] = jnp.zeros_like(v)
    elif method.name == "bitfit":
        for k, v in init_base(cfg, next(keys)).items():
            if k.endswith(".b") and "ln" not in k:
                p[f"delta.{k}"] = jnp.zeros_like(v)
    elif method.name == "adapter":
        # Houlsby-style: two bottlenecks per block (post-attn, post-mlp).
        for i in range(cfg.layers):
            for spot in ("attn", "mlp"):
                b = f"adpt.blk{i}.{spot}"
                p[f"{b}.down.w"] = _dense_init(next(keys), cfg.d, (cfg.d, method.m))
                p[f"{b}.down.b"] = jnp.zeros((method.m,), jnp.float32)
                p[f"{b}.up.w"] = jnp.zeros((method.m, cfg.d), jnp.float32)
                p[f"{b}.up.b"] = jnp.zeros((cfg.d,), jnp.float32)
    elif method.name == "lora":
        for k in sites:
            d1 = _site_dims(cfg, k)[0]
            d2 = _site_dims(cfg, k)[1]
            p[f"lora.{k}.a"] = _dense_init(next(keys), d1, (method.r, d2))
            p[f"lora.{k}.b"] = jnp.zeros((d1, method.r), jnp.float32)
    elif method.name in ("fourierft", "randbasis", "orthobasis"):
        for k in sites:
            p[f"spec.{k}.c"] = jnp.zeros((method.n,), jnp.float32)
    elif method.name == "lp":
        pass
    else:
        raise ValueError(f"unknown method {method.name}")

    for k, shp in head_shapes(cfg, loss).items():
        if k in p:
            continue  # ff already materialized the head delta
        if not method.head:
            continue  # frozen head: no trainable head tensors at all
        if k.startswith("delta."):
            p[k] = jnp.zeros(shp, jnp.float32)  # delta on a base head
        elif k.endswith(".w"):
            p[k] = _dense_init(next(keys), shp[0], shp)
        else:
            p[k] = jnp.zeros(shp, jnp.float32)
    return p


def _site_dims(cfg: ModelCfg, key: str) -> tuple[int, int]:
    if cfg.kind in ("mlp", "denoiser"):
        return (cfg.hidden, cfg.hidden)
    return (cfg.d, cfg.d)


def static_shapes(cfg: ModelCfg, method: MethodCfg) -> "OrderedDict[str, tuple]":
    """Frozen non-base inputs supplied by the rust coordinator each call:
    the shared spectral entry matrix E, or the ablation basis pair."""
    s = OrderedDict()
    d1, d2 = _site_dims(cfg, adapted_weight_keys(cfg)[0]) if adapted_weight_keys(cfg) else (cfg.d, cfg.d)
    if method.name == "fourierft":
        s["entries"] = ("i32", (2, method.n))
    elif method.name in ("randbasis", "orthobasis"):
        s["entries"] = ("i32", (2, method.n))
        s["basis1"] = ("f32", (d1, d1))
        s["basis2"] = ("f32", (d2, d2))
    return s


# ---------------------------------------------------------------------------
# Effective weights + forward passes
# ---------------------------------------------------------------------------


def effective_weight(cfg, method, base, adapt, statics, key, scaling):
    """W_eff for base tensor ``key`` under the active method.

    ``scaling`` is the runtime scalar (alpha for spectral methods, the
    LoRA scaling for lora; unused otherwise).
    """
    w = base[key]
    if method.name == "ff":
        return w + adapt[f"delta.{key}"]
    if method.name == "bitfit":
        dk = f"delta.{key}"
        return w + adapt[dk] if dk in adapt else w
    if method.name == "lora" and key in _adapted_set(cfg):
        return w + adapt[f"lora.{key}.b"] @ adapt[f"lora.{key}.a"] * scaling
    if method.name == "fourierft" and key in _adapted_set(cfg):
        d1, d2 = w.shape
        return w + fourier_delta(statics["entries"], adapt[f"spec.{key}.c"],
                                 scaling, d1, d2)
    if method.name in ("randbasis", "orthobasis") and key in _adapted_set(cfg):
        d1, d2 = w.shape
        f = jnp.zeros((d1, d2), jnp.float32).at[
            statics["entries"][0], statics["entries"][1]
        ].set(adapt[f"spec.{key}.c"])
        return w + statics["basis1"] @ f @ statics["basis2"].T * scaling
    return w


@functools.lru_cache(maxsize=None)
def _adapted_set_cached(cfg: ModelCfg) -> frozenset:
    return frozenset(adapted_weight_keys(cfg))


def _adapted_set(cfg) -> frozenset:
    return _adapted_set_cached(cfg)


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: ModelCfg, x, wq, wk, wv, wo, bq, bk_, bv, bo, causal: bool):
    b, t, d = x.shape
    h, dh = cfg.heads, cfg.d // cfg.heads

    def split(z):
        return z.reshape(b, t, h, dh).transpose(0, 2, 1, 3)

    q = split(x @ wq + bq)
    k = split(x @ wk + bk_)
    v = split(x @ wv + bv)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo + bo


def _maybe_adapter(adapt, tag, x):
    """Houlsby bottleneck with residual; identity when the method has none."""
    dw = adapt.get(f"{tag}.down.w")
    if dw is None:
        return x
    h = jax.nn.gelu(x @ dw + adapt[f"{tag}.down.b"])
    return x + h @ adapt[f"{tag}.up.w"] + adapt[f"{tag}.up.b"]


def transformer_trunk(cfg, method, base, adapt, statics, x, scaling, causal):
    """Shared encoder/decoder/vit trunk over embedded tokens x: [B,T,D]."""
    W = lambda k: effective_weight(cfg, method, base, adapt, statics, k, scaling)
    for i in range(cfg.layers):
        blk = f"blk{i}"
        h = _layer_norm(x, base[f"{blk}.ln1.g"], base[f"{blk}.ln1.b"])
        h = _attention(
            cfg, h,
            W(f"{blk}.attn.wq.w"), W(f"{blk}.attn.wk.w"),
            W(f"{blk}.attn.wv.w"), W(f"{blk}.attn.wo.w"),
            _bias(cfg, method, base, adapt, f"{blk}.attn.wq.b"),
            _bias(cfg, method, base, adapt, f"{blk}.attn.wk.b"),
            _bias(cfg, method, base, adapt, f"{blk}.attn.wv.b"),
            _bias(cfg, method, base, adapt, f"{blk}.attn.wo.b"),
            causal,
        )
        h = _maybe_adapter(adapt, f"adpt.blk{i}.attn", h)
        x = x + h
        h = _layer_norm(x, base[f"{blk}.ln2.g"], base[f"{blk}.ln2.b"])
        h = jax.nn.gelu(h @ W(f"{blk}.mlp.fc1.w")
                        + _bias(cfg, method, base, adapt, f"{blk}.mlp.fc1.b"))
        h = h @ W(f"{blk}.mlp.fc2.w") + _bias(cfg, method, base, adapt, f"{blk}.mlp.fc2.b")
        h = _maybe_adapter(adapt, f"adpt.blk{i}.mlp", h)
        x = x + h
    return _layer_norm(x, base["ln_f.g"], base["ln_f.b"])


def _bias(cfg, method, base, adapt, key):
    b = base[key]
    if method.name == "ff":
        return b + adapt[f"delta.{key}"]
    if method.name == "bitfit":
        dk = f"delta.{key}"
        return b + adapt[dk] if dk in adapt else b
    return b


def forward(cfg: ModelCfg, method: MethodCfg, loss: str, base, adapt, statics,
            x, scaling):
    """Model forward -> logits.

    encoder/vit: [B, classes-or-1] off the first token; decoder: [B, T, V];
    mlp: [B, classes].
    """
    if cfg.kind == "denoiser":
        W = lambda k: effective_weight(cfg, method, base, adapt, statics, k, scaling)
        h = jnp.tanh(x @ W("fc_in.w") + _bias(cfg, method, base, adapt, "fc_in.b"))
        h = jnp.tanh(h @ W("w2.w") + _bias(cfg, method, base, adapt, "w2.b"))
        out = h @ W("fc_out.w") + _bias(cfg, method, base, adapt, "fc_out.b")
        return jax.nn.sigmoid(out)  # pixels in [0, 1]

    if cfg.kind == "mlp":
        W = lambda k: effective_weight(cfg, method, base, adapt, statics, k, scaling)
        h = jnp.tanh(x @ W("w1.w") + _bias(cfg, method, base, adapt, "w1.b"))
        h = jnp.tanh(h @ W("w2.w") + _bias(cfg, method, base, adapt, "w2.b"))
        hw = base["head.w"] + adapt.get("delta.head.w", 0.0)
        hb = base["head.b"] + adapt.get("delta.head.b", 0.0)
        return h @ hw + hb

    if cfg.kind in ("encoder", "decoder"):
        tok = base["tok_emb"][x]  # x: i32 [B, T]
        h = tok + base["pos_emb"][None, : x.shape[1]]
    else:  # vit: x f32 [B, img, img, C]
        b = x.shape[0]
        pp, ch = cfg.patch, cfg.channels
        g = cfg.img // pp
        patches = x.reshape(b, g, pp, g, pp, ch).transpose(0, 1, 3, 2, 4, 5)
        patches = patches.reshape(b, g * g, pp * pp * ch)
        emb = patches @ base["patch.w"] + base["patch.b"]
        cls = jnp.broadcast_to(base["cls_tok"], (b, 1, cfg.d))
        h = jnp.concatenate([cls, emb], axis=1) + base["pos_emb"][None]

    h = transformer_trunk(cfg, method, base, adapt, statics, h,
                          scaling, causal=(cfg.kind == "decoder"))
    if cfg.kind == "decoder" or loss == "mlm":
        return h @ adapt["head.w"] + adapt["head.b"]  # [B, T, V]
    return h[:, 0] @ adapt["head.w"] + adapt["head.b"]  # first/[CLS] token
