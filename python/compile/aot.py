"""AOT driver: lower every manifest artifact to HLO *text* + meta JSON.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Outputs per ArtifactSpec under ``artifacts/``:

  <name>.step.hlo.txt   fused train/eval step (lr=0 => pure eval)
  <name>.init.hlo.txt   seed -> initial adapt/m/v tensors
  <name>.meta.json      tensor-level ABI: ordered inputs/outputs with
                        name/dtype/shape/role + param-count accounting

plus per architecture ``<model>.base.hlo.txt`` (seed -> base params) and
per (d, n) FourierFT shape ``delta_d{d}_n{n}.hlo.txt`` (E, c, alpha -> ΔW,
used by the rust serving/merge path), and a global ``manifest.json``.

Python runs ONLY here (build time); the rust coordinator never imports it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from collections import OrderedDict

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layers, train
from .configs import ArtifactSpec, build_manifest, manifest_dict

DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def sortd(d: "OrderedDict") -> "OrderedDict":
    """Re-key an OrderedDict in sorted order. jax flattens OrderedDicts in
    *insertion* order (unlike plain dicts, which flatten sorted), so every
    dict that crosses the HLO ABI is normalized to sorted order — the meta
    JSON records the same order and the rust side relies on it."""
    return OrderedDict((k, d[k]) for k in sorted(d))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _zeros(shapes: "OrderedDict[str, tuple]"):
    return OrderedDict((k, jnp.zeros(s, jnp.float32)) for k, s in shapes.items())


def _spec_arrays(spec: ArtifactSpec):
    """Abstract example arrays for lowering the step fn, plus IO metadata."""
    cfg, method = spec.model, spec.method
    base = sortd(layers.init_base(cfg, jax.random.PRNGKey(0)))
    adapt = sortd(layers.init_adapt(cfg, method, spec.loss, jax.random.PRNGKey(1)))
    statics = sortd(OrderedDict(
        (k, jnp.zeros(shape, DTYPES[dt]))
        for k, (dt, shape) in layers.static_shapes(cfg, method).items()
    ))
    scalars = sortd(OrderedDict(
        (k, jnp.zeros((), jnp.float32)) for k in train.scalar_names()))
    batch = sortd(OrderedDict(
        (k, jnp.zeros(shape, DTYPES[dt]))
        for k, (dt, shape) in train.batch_shapes(spec).items()
    ))
    return base, adapt, statics, scalars, batch


def _io_meta(groups: "list[tuple[str, OrderedDict]]"):
    out = []
    for role, d in groups:
        for k, v in d.items():
            out.append({
                "name": k,
                "role": role,
                "dtype": "i32" if v.dtype == jnp.int32 else "f32",
                "shape": list(v.shape),
            })
    return out


def lower_step(spec: ArtifactSpec, outdir: str) -> dict:
    base, adapt, statics, scalars, batch = _spec_arrays(spec)
    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
    v_ = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())

    def step(base, adapt, m, v, statics, scalars, batch):
        a2, m2, v2, loss, logits = train.train_step(
            spec, base, adapt, m, v, statics, scalars, batch)
        return sortd(a2), sortd(m2), sortd(v2), loss, logits

    lowered = jax.jit(step, keep_unused=True).lower(base, adapt, m, v_, statics, scalars, batch)
    text = to_hlo_text(lowered)
    path = os.path.join(outdir, f"{spec.name}.step.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    # Input order matches jax's flattening of the positional args: every
    # OrderedDict is pre-sorted by sortd(), args flatten left-to-right.
    inputs = _io_meta([
        ("base", base), ("adapt", adapt), ("opt_m", m),
        ("opt_v", v_), ("static", statics),
        ("scalar", scalars), ("batch", batch),
    ])
    logits_shape = jax.eval_shape(
        lambda *a: train.model_logits(spec, *a), base, adapt, statics, scalars, batch
    )
    outputs = _io_meta([
        ("adapt", adapt), ("opt_m", m), ("opt_v", v_),
    ]) + [
        {"name": "loss", "role": "loss", "dtype": "f32", "shape": []},
        {"name": "logits", "role": "logits", "dtype": "f32",
         "shape": list(logits_shape.shape)},
    ]
    return {"step_hlo": os.path.basename(path), "inputs": inputs, "outputs": outputs}


def lower_init(spec: ArtifactSpec, outdir: str) -> str:
    """seed (i32 scalar) -> initial (adapt, m, v) tensors."""
    def init(seed):
        key = jax.random.PRNGKey(seed)
        adapt = sortd(layers.init_adapt(spec.model, spec.method, spec.loss, key))
        zeros = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
        return adapt, zeros, zeros

    lowered = jax.jit(init, keep_unused=True).lower(jnp.zeros((), jnp.int32))
    path = os.path.join(outdir, f"{spec.name}.init.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    return os.path.basename(path)


def lower_base(model_cfg, outdir: str) -> dict:
    def init(seed):
        return sortd(layers.init_base(model_cfg, jax.random.PRNGKey(seed)))

    lowered = jax.jit(init, keep_unused=True).lower(jnp.zeros((), jnp.int32))
    path = os.path.join(outdir, f"{model_cfg.name}.base.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    base = layers.init_base(model_cfg, jax.random.PRNGKey(0))
    tensors = [
        {"name": k, "dtype": "f32", "shape": list(v.shape)}
        for k, v in sorted(base.items())
    ]
    return {"base_hlo": os.path.basename(path), "tensors": tensors}


def lower_delta(d: int, n: int, outdir: str) -> str:
    """Standalone ΔW reconstruction (E, c, alpha) -> [d, d] for the rust
    adapter-merge / serving path; exercises the same L1 Pallas kernel."""
    def delta(entries, coeffs, alpha):
        return layers.fourier_delta(entries, coeffs, alpha, d, d)

    lowered = jax.jit(delta).lower(
        jnp.zeros((2, n), jnp.int32), jnp.zeros((n,), jnp.float32),
        jnp.zeros((), jnp.float32),
    )
    name = f"delta_d{d}_n{n}.hlo.txt"
    with open(os.path.join(outdir, name), "w") as f:
        f.write(to_hlo_text(lowered))
    return name


def trainable_counts(spec: ArtifactSpec) -> dict:
    adapt = layers.init_adapt(spec.model, spec.method, spec.loss, jax.random.PRNGKey(0))
    head = sum(int(v.size) for k, v in adapt.items()
               if k.startswith("head.") or k.startswith("delta.head."))
    total = sum(int(v.size) for v in adapt.values())
    return {"trainable": total, "trainable_ex_head": total - head, "head": head}


def source_fingerprint() -> str:
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    specs = build_manifest()
    if args.only:
        specs = [s for s in specs if args.only in s.name]

    manifest = {"fingerprint": source_fingerprint(), "specs": [], "bases": {},
                "deltas": []}
    # Incremental mode: merge the previous manifest so a filtered rebuild
    # does not orphan the untouched artifact families.
    prev_path = os.path.join(args.out, "manifest.json")
    if args.only and os.path.exists(prev_path):
        with open(prev_path) as f:
            prev = json.load(f)
        rebuilt = {s.name for s in specs}
        manifest["specs"] = [e for e in prev.get("specs", []) if e["name"] not in rebuilt]
        manifest["bases"] = prev.get("bases", {})
        manifest["deltas"] = prev.get("deltas", [])

    # Bases of models touched this run are always re-lowered (their init
    # may have changed); untouched models keep their previous entries.
    done_models: set = set(manifest["bases"].keys()) - {s.model.name for s in specs}
    done_deltas = {(e["d"], e["n"]) for e in manifest["deltas"]}
    for i, spec in enumerate(specs):
        print(f"[{i + 1}/{len(specs)}] {spec.name}", flush=True)
        entry = dict(manifest_dict_entry(spec))
        entry.update(lower_step(spec, args.out))
        entry["init_hlo"] = lower_init(spec, args.out)
        entry["counts"] = trainable_counts(spec)
        manifest["specs"].append(entry)

        if spec.model.name not in done_models:
            done_models.add(spec.model.name)
            manifest["bases"][spec.model.name] = lower_base(spec.model, args.out)
        if spec.method.name == "fourierft":
            d = spec.model.d if spec.model.kind != "mlp" else spec.model.hidden
            key = (d, spec.method.n)
            if key not in done_deltas:
                done_deltas.add(key)
                manifest["deltas"].append(
                    {"d": d, "n": spec.method.n,
                     "hlo": lower_delta(d, spec.method.n, args.out)})

        # write meta sidecar per spec
        with open(os.path.join(args.out, f"{spec.name}.meta.json"), "w") as f:
            json.dump(entry, f, indent=1)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(specs)} artifact families to {args.out}")


def manifest_dict_entry(spec: ArtifactSpec) -> dict:
    from dataclasses import asdict

    return {"name": spec.name, "model": asdict(spec.model),
            "method": asdict(spec.method), "loss": spec.loss}


if __name__ == "__main__":
    main()
