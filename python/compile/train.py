"""Layer-2 training step: losses + Adam(W), fused train/eval in one HLO.

One artifact per (model, method, loss) serves both training and evaluation:
the step returns ``(adapt', m', v', loss, logits)`` and running it with
``lr = 0`` is a pure forward pass (Adam moments still roll but the rust
coordinator discards them in eval mode). This halves the artifact count and
guarantees train/eval numerics share one compiled module.

All hyperparameters that do not change tensor *shapes* (lr, weight decay,
the FourierFT scaling alpha / LoRA scaling, Adam step t) are runtime scalar
inputs, so the rust coordinator can sweep them without re-lowering.
"""

from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from . import layers
from .configs import ArtifactSpec

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def batch_shapes(spec: ArtifactSpec) -> "OrderedDict[str, tuple]":
    """Batch tensors the coordinator feeds each step (name -> (dtype, shape))."""
    cfg, loss = spec.model, spec.loss
    b = cfg.batch
    s = OrderedDict()
    if cfg.kind == "mlp":
        s["x"] = ("f32", (b, 2))
        s["y"] = ("i32", (b,))
    elif cfg.kind == "denoiser":
        pix = cfg.img * cfg.img * cfg.channels
        s["x"] = ("f32", (b, pix))  # noisy pixels
        s["y"] = ("f32", (b, pix))  # clean pixels
    elif cfg.kind == "vit":
        s["x"] = ("f32", (b, cfg.img, cfg.img, cfg.channels))
        s["y"] = ("i32", (b,))
    elif cfg.kind == "encoder":
        s["x"] = ("i32", (b, cfg.seqlen))
        if loss == "mse":
            s["y"] = ("f32", (b,))
        elif loss == "mlm":
            s["y"] = ("i32", (b, cfg.seqlen))
            s["mask"] = ("f32", (b, cfg.seqlen))
        else:
            s["y"] = ("i32", (b,))
    else:  # decoder, lm loss
        s["x"] = ("i32", (b, cfg.seqlen))
        s["y"] = ("i32", (b, cfg.seqlen))
        s["mask"] = ("f32", (b, cfg.seqlen))
    return s


def compute_loss(spec: ArtifactSpec, logits, batch):
    loss_kind = spec.loss
    if loss_kind == "ce":
        lp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(lp, batch["y"][:, None], axis=-1)
        return nll.mean()
    if loss_kind == "mse":
        return ((logits[:, 0] - batch["y"]) ** 2).mean()
    if loss_kind == "mseimg":
        return ((logits - batch["y"]) ** 2).mean()
    # lm / mlm: per-token CE with a validity mask.
    lp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(lp, batch["y"][..., None], axis=-1)[..., 0]
    m = batch["mask"]
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def model_logits(spec: ArtifactSpec, base, adapt, statics, scalars, batch):
    return layers.forward(spec.model, spec.method, spec.loss, base, adapt,
                          statics, batch["x"], scalars["scaling"])


def train_step(spec: ArtifactSpec, base, adapt, m, v, statics, scalars, batch):
    """One fused Adam(W) step. scalars: step (1-based, f32), lr, wd, scaling."""

    def loss_fn(a):
        logits = model_logits(spec, base, a, statics, scalars, batch)
        return compute_loss(spec, logits, batch), logits

    (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(adapt)

    t = scalars["step"]
    lr, wd = scalars["lr"], scalars["wd"]
    lr_head = scalars["lr_head"]
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_a, new_m, new_v = OrderedDict(), OrderedDict(), OrderedDict()
    for k in adapt:
        g = grads[k]
        mk = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        upd = (mk / bc1) / (jnp.sqrt(vk / bc2) + ADAM_EPS)
        # The paper tunes the task head with its own (smaller) learning
        # rate — spectral coefficients want lr ~50x larger than dense
        # head weights (Appendix B, Tables 9-12).
        k_lr = lr_head if (k.startswith("head.") or k.startswith("delta.head.")) else lr
        new_a[k] = adapt[k] - k_lr * upd - k_lr * wd * adapt[k]
        new_m[k] = mk
        new_v[k] = vk
    return new_a, new_m, new_v, loss, logits


def scalar_names() -> list[str]:
    return ["step", "lr", "lr_head", "wd", "scaling"]
