"""Layer-1 Pallas kernels: FourierFT spectral reconstruction on the MXU.

Hardware adaptation (DESIGN.md §5). The paper's reference implementation
calls ``torch.fft.ifft2`` — a cuFFT launch on GPU. TPUs have no FFT unit;
the efficient primitive is the 128x128 systolic matmul (MXU). Because the
spectral matrix F is zero except at ``n`` trainable entries, the 2D inverse
DFT collapses to a *rank-n trigonometric expansion*:

    Re(S)[p, q] = 1/(d1 d2) * sum_l c_l * cos(2 pi (p j_l / d1 + q k_l / d2))
                = 1/(d1 d2) * [ (Cu . c) @ Cv^T - (Su . c) @ Sv^T ]

i.e. two [d1, n] x [n, d2] matmuls whose operands are generated *in-VMEM*
from iota + gathered entry frequencies — no d1 x d2 dense spectral matrix is
ever materialized in HBM, and no FFT is needed. FLOPs = 4 d1 d2 n versus
O(d1 d2 log(d1 d2)) for the dense FFT; for the paper's operating points
(n <= 2 d r << d^2) the matmul form is both cheaper and MXU-native.

Grid: (d1 / BM, d2 / BN, n / BK), f32 accumulation in the revisited output
block. Per-step VMEM = BM*BK + BN*BK trig operands + BM*BN accumulator
floats; at BM=BN=64, BK=128 that is ~145 KiB, far under the ~16 MiB VMEM
budget, leaving room for double buffering of the entry stream (see
``vmem_bytes`` below, asserted in tests).

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot execute. Numerics are identical.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pad_entries(entries: jnp.ndarray, coeffs: jnp.ndarray, bk: int):
    """Pad the reduction dim to a multiple of bk with zero-coefficient
    entries at (0, 0) — cos(0) * 0 contributes nothing."""
    n = coeffs.shape[0]
    n_pad = (-n) % bk
    if n_pad:
        entries = jnp.pad(entries, ((0, 0), (0, n_pad)))
        coeffs = jnp.pad(coeffs, (0, n_pad))
    return entries, coeffs, n + n_pad


def _delta_kernel(e_ref, c_ref, o_ref, *, d1: int, d2: int):
    """One (BM, BN) output tile, one BK entry slab.

    The n-axis is the innermost grid dimension, so the same output block is
    revisited across slabs and serves as the f32 accumulator (standard
    Pallas matmul reduction pattern).
    """
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bm, bn = o_ref.shape
    # Absolute output coordinates of this tile, generated from iota — the
    # trig operands never touch HBM.
    p = (pl.program_id(0) * bm + jax.lax.iota(jnp.float32, bm))[:, None]  # [BM,1]
    q = (pl.program_id(1) * bn + jax.lax.iota(jnp.float32, bn))[:, None]  # [BN,1]
    j = e_ref[0, :].astype(jnp.float32)[None, :]  # [1, BK]
    k = e_ref[1, :].astype(jnp.float32)[None, :]  # [1, BK]
    c = c_ref[...][None, :]  # [1, BK]

    two_pi = 2.0 * math.pi
    tu = two_pi / d1 * p * j  # [BM, BK]
    tv = two_pi / d2 * q * k  # [BN, BK]
    # Fold the coefficients into the left operand, contract over the slab on
    # the MXU: [BM, BK] @ [BK, BN].
    cu = jnp.cos(tu) * c
    su = jnp.sin(tu) * c
    o_ref[...] += jnp.dot(cu, jnp.cos(tv).T) - jnp.dot(su, jnp.sin(tv).T)


@functools.partial(jax.jit, static_argnames=("d1", "d2", "block"))
def spectral_to_delta(
    entries: jnp.ndarray,
    coeffs: jnp.ndarray,
    alpha: jnp.ndarray | float,
    *,
    d1: int,
    d2: int,
    block: tuple[int, int, int] = (64, 64, 128),
) -> jnp.ndarray:
    """FourierFT Eq. 2-3: Delta_W = alpha * Re(IDFT2(ToDense(E, c))).

    entries: i32[2, n], coeffs: f32[n]; returns f32[d1, d2]. ``alpha`` may be
    a traced scalar so the L3 coordinator can sweep the scaling value without
    recompiling the artifact. Matches ``ref.spectral_to_delta_ifft`` (the
    paper's ``torch.fft.ifft2(F).real * alpha``) to f32 tolerance.
    """
    bm, bn, bk = block
    bm, bn = min(bm, d1), min(bn, d2)
    entries, coeffs, n_padded = _pad_entries(entries, coeffs, bk)
    grid = (pl.cdiv(d1, bm), pl.cdiv(d2, bn), n_padded // bk)

    out = pl.pallas_call(
        functools.partial(_delta_kernel, d1=d1, d2=d2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, bk), lambda i, j, s: (0, s)),
            pl.BlockSpec((bk,), lambda i, j, s: (s,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d1, d2), jnp.float32),
        interpret=True,
    )(entries, coeffs)
    scale = jnp.asarray(alpha, jnp.float32) / (d1 * d2)
    return out * scale


def vmem_bytes(block: tuple[int, int, int]) -> int:
    """Static VMEM footprint estimate for one grid step (f32), used by the
    DESIGN.md roofline analysis and asserted in tests to stay under budget."""
    bm, bn, bk = block
    # cu, su: [bm, bk]; cv, sv: [bn, bk]; entry slab + coeffs; accumulator.
    return 4 * (2 * bm * bk + 2 * bn * bk + bm * bn + 3 * bk)


def mxu_flops(d1: int, d2: int, n: int) -> int:
    """Total matmul FLOPs of the rank-n reconstruction (2 matmuls, 2 ops/MAC)."""
    return 4 * d1 * d2 * n
