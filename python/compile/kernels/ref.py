"""Pure-jnp reference oracles for the FourierFT reconstruction kernels.

These are the ground truth that the Pallas kernels in ``fourier.py`` are
tested against (``python/tests/test_kernel.py``). They deliberately mirror
the paper's PyTorch pseudocode (Algorithm 1):

    F = zeros(d1, d2); F[E[0], E[1]] = c
    Delta_W = torch.fft.ifft2(F).real * alpha

``jnp.fft.ifft2`` uses the same 1/(d1*d2) normalization as torch, so the
two agree bit-for-bit up to f32 rounding.
"""

from __future__ import annotations

import jax.numpy as jnp


def to_dense(entries: jnp.ndarray, coeffs: jnp.ndarray, d1: int, d2: int) -> jnp.ndarray:
    """Eq. 2 (ToDense): scatter n coefficients into a d1 x d2 zero matrix.

    entries: i32[2, n] row/col spectral indices (rows in [0, d1), cols in [0, d2)).
    coeffs:  f32[n] trainable spectral coefficients.
    """
    f = jnp.zeros((d1, d2), dtype=coeffs.dtype)
    return f.at[entries[0], entries[1]].set(coeffs)


def spectral_to_delta_ifft(
    entries: jnp.ndarray, coeffs: jnp.ndarray, d1: int, d2: int, alpha: float
) -> jnp.ndarray:
    """Eq. 2-3 via a dense inverse FFT — the paper's reference semantics."""
    f = to_dense(entries, coeffs, d1, d2)
    return jnp.fft.ifft2(f).real.astype(coeffs.dtype) * alpha


def spectral_to_delta_matmul(
    entries: jnp.ndarray, coeffs: jnp.ndarray, d1: int, d2: int, alpha: float
) -> jnp.ndarray:
    """Eq. 2-3 via the real-decomposed trig rank-n expansion (no FFT).

    Re(S)[p, q] = 1/(d1 d2) * sum_l c_l cos(2 pi (p j_l / d1 + q k_l / d2))
                = 1/(d1 d2) * [ (Cu * c) @ Cv^T - (Su * c) @ Sv^T ]

    with Cu[p, l] = cos(2 pi p j_l / d1) etc. This is the MXU-friendly form
    the Pallas kernel implements (two [d1, n] x [n, d2] matmuls).
    """
    j = entries[0].astype(jnp.float32)  # [n]
    k = entries[1].astype(jnp.float32)  # [n]
    p = jnp.arange(d1, dtype=jnp.float32)[:, None]  # [d1, 1]
    q = jnp.arange(d2, dtype=jnp.float32)[:, None]  # [d2, 1]
    tu = 2.0 * jnp.pi * p * j[None, :] / d1  # [d1, n]
    tv = 2.0 * jnp.pi * q * k[None, :] / d2  # [d2, n]
    cu, su = jnp.cos(tu), jnp.sin(tu)
    cv, sv = jnp.cos(tv), jnp.sin(tv)
    c = coeffs[None, :]
    s = (cu * c) @ cv.T - (su * c) @ sv.T
    return s.astype(coeffs.dtype) * (alpha / (d1 * d2))


def lora_delta(a: jnp.ndarray, b: jnp.ndarray, scaling: float) -> jnp.ndarray:
    """LoRA weight change: Delta_W = (B @ A) * scaling, B: [d1, r], A: [r, d2]."""
    return (b @ a) * scaling


def basis_delta(
    entries: jnp.ndarray,
    coeffs: jnp.ndarray,
    b1: jnp.ndarray,
    b2: jnp.ndarray,
    alpha: float,
) -> jnp.ndarray:
    """Table 6 ablation: Delta_W = alpha * B1 @ ToDense(E, c) @ B2^T with an
    arbitrary (random / orthogonal) basis pair instead of the Fourier basis."""
    d1, d2 = b1.shape[0], b2.shape[0]
    f = to_dense(entries, coeffs, d1, d2)
    return (b1 @ f @ b2.T).astype(coeffs.dtype) * alpha
