"""Experiment configuration: model architectures, PEFT methods, and the
artifact manifest that ``aot.py`` lowers to HLO.

Scaling note (DESIGN.md §2): the paper fine-tunes RoBERTa (d=768/1024),
GPT-2 (d=1024/1280), LLaMA (d=4096/5120) and ViT (d=768/1024). This repo
re-creates every experiment with from-scratch "sim" models at laptop scale
(d=128 "base", d=192 "large"), keeping the paper's *ratios*: FourierFT's
per-site parameter count n is matched against LoRA's 2*d*r exactly as in
Fig. 4 ({r=4 <-> n=2*d*4}, {r=8 <-> n=2*d*8}).
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelCfg:
    """Architecture of one sim model. ``kind`` selects the forward fn."""

    name: str
    kind: str  # mlp | encoder | decoder | vit
    d: int = 128
    layers: int = 4
    heads: int = 4
    dff: int = 256
    vocab: int = 1000
    seqlen: int = 32
    classes: int = 4  # classifier head width (encoder/vit/mlp)
    img: int = 32  # vit image side
    patch: int = 4  # vit patch side
    channels: int = 3
    hidden: int = 64  # mlp hidden width (Fig. 7 uses 64x64)
    batch: int = 32

    @property
    def tokens(self) -> int:
        """Sequence length seen by the transformer blocks."""
        if self.kind == "vit":
            return (self.img // self.patch) ** 2 + 1  # + [CLS]
        return self.seqlen


@dataclass(frozen=True)
class MethodCfg:
    """One PEFT method instance. ``name`` in:

    ff        full fine-tuning (dense delta per base tensor; Adam on the
              delta is trajectory-identical to Adam on the weight)
    lp        linear probe — classifier head only
    bitfit    bias deltas only (Zaken et al. 2021)
    adapter   Houlsby-style bottleneck adapters after attn + mlp
    lora      Delta_W = B @ A * scaling at W_q / W_v     (Hu et al. 2021)
    fourierft Delta_W = alpha * Re(IDFT2(ToDense(E, c))) (this paper)
    randbasis Table 6 ablation: Gaussian basis pair instead of Fourier
    orthobasis Table 6 ablation: random orthogonal basis pair
    """

    name: str
    r: int = 0  # lora rank
    n: int = 0  # fourierft spectral coefficients per site
    m: int = 0  # adapter bottleneck width
    head: bool = True  # train the task head (False = frozen random head,
    #                    used by the Figure-7 expressivity protocol)

    @property
    def tag(self) -> str:
        base = self.name
        if self.name == "lora":
            base = f"lora_r{self.r}"
        elif self.name in ("fourierft", "randbasis", "orthobasis"):
            base = f"{self.name}_n{self.n}"
        elif self.name == "adapter":
            base = f"adapter_m{self.m}"
        return base if self.head else f"{base}_fh"


@dataclass(frozen=True)
class ArtifactSpec:
    """One lowered HLO module family: init + fused train/eval step."""

    model: ModelCfg
    method: MethodCfg
    loss: str = "ce"  # ce | mse | lm

    @property
    def name(self) -> str:
        return f"{self.model.name}__{self.method.tag}__{self.loss}"


# ---------------------------------------------------------------------------
# Model zoo (sim-scale stand-ins for the paper's base models)
# ---------------------------------------------------------------------------

MLP = ModelCfg(name="mlp", kind="mlp", hidden=64, classes=8, batch=64)

ENC_BASE = ModelCfg(name="enc_base", kind="encoder", d=128, layers=4, heads=4,
                    dff=256, vocab=1000, seqlen=32, classes=3)
ENC_LARGE = ModelCfg(name="enc_large", kind="encoder", d=192, layers=6, heads=6,
                     dff=384, vocab=1000, seqlen=32, classes=3)

DEC_MED = ModelCfg(name="dec_med", kind="decoder", d=128, layers=4, heads=4,
                   dff=256, vocab=1000, seqlen=48)
DEC_LARGE = ModelCfg(name="dec_large", kind="decoder", d=192, layers=6, heads=6,
                     dff=384, vocab=1000, seqlen=48)

DENOISER = ModelCfg(name="denoiser", kind="denoiser", hidden=256, img=16,
                    channels=3, batch=32)

VIT_BASE = ModelCfg(name="vit_base", kind="vit", d=128, layers=4, heads=4,
                    dff=256, img=32, patch=4, classes=200, batch=32)
VIT_LARGE = ModelCfg(name="vit_large", kind="vit", d=192, layers=6, heads=6,
                     dff=384, img=32, patch=4, classes=200, batch=32)

MODELS = {m.name: m for m in
          (MLP, ENC_BASE, ENC_LARGE, DEC_MED, DEC_LARGE, VIT_BASE, VIT_LARGE,
           DENOISER)}


def _m(name: str, **kw) -> MethodCfg:
    return MethodCfg(name=name, **kw)


# Fig. 4 grids (scaled: paper used r={1,2,4,6,8,15}, n={50,100,200,1000,
# 6144=2*768*4, 12288=2*768*8} at d=768; we keep the same structure at d=128:
# 2*128*4=1024, 2*128*8=2048).
LORA_GRID = (1, 2, 4, 6, 8, 15)
FFT_GRID_BASE = (16, 32, 64, 256, 1024, 2048)
FFT_GRID_LARGE = (24, 48, 96, 384, 1536, 3072)  # matched at d=192


def build_manifest() -> list[ArtifactSpec]:
    specs: list[ArtifactSpec] = []
    A = specs.append

    # --- Figure 7: 2D synthetic expressivity (64x64 hidden layer) ---------
    for meth in (_m("ff"), _m("lora", r=1), _m("fourierft", n=128)):
        A(ArtifactSpec(MLP, meth, "ce"))
    # frozen-head variants: the paper's protocol trains ONLY the hidden
    # layer, which is where the LoRA-r=1 expressivity bottleneck appears
    for meth in (_m("lora", r=1, head=False), _m("fourierft", n=128, head=False),
                 _m("ff", head=False)):
        A(ArtifactSpec(MLP, meth, "ce"))

    # --- Pretraining artifacts (masked-token objective for encoders; the
    #     decoder/vit ff artifacts below double as their pretrain steps) ----
    A(ArtifactSpec(ENC_BASE, _m("ff"), "mlm"))
    A(ArtifactSpec(ENC_LARGE, _m("ff"), "mlm"))

    # --- Table 2 / Figure 4 / 5 / 6 / Table 6: GLUE-sim, encoder base -----
    enc_methods = [_m("ff"), _m("bitfit"), _m("adapter", m=8)]
    enc_methods += [_m("lora", r=r) for r in LORA_GRID]
    enc_methods += [_m("fourierft", n=n) for n in FFT_GRID_BASE]
    enc_methods += [_m("randbasis", n=64), _m("orthobasis", n=64)]
    for meth in enc_methods:
        A(ArtifactSpec(ENC_BASE, meth, "ce"))
    # STS-B-sim is a regression task (PCC metric) -> mse loss variants.
    for meth in (_m("ff"), _m("bitfit"), _m("lora", r=8), _m("fourierft", n=64),
                 _m("fourierft", n=256)):
        A(ArtifactSpec(ENC_BASE, meth, "mse"))

    # --- Table 2 large + Table 6 large -------------------------------------
    for meth in (_m("ff"), _m("adapter", m=8), _m("lora", r=8),
                 _m("fourierft", n=96), _m("fourierft", n=384),
                 _m("randbasis", n=96), _m("orthobasis", n=96)):
        A(ArtifactSpec(ENC_LARGE, meth, "ce"))
    for meth in (_m("ff"), _m("lora", r=8), _m("fourierft", n=96)):
        A(ArtifactSpec(ENC_LARGE, meth, "mse"))

    # --- Table 3: E2E-sim NLG (decoder) + Table 4: instruction-sim --------
    for meth in (_m("ff"), _m("adapter", m=8), _m("lora", r=4), _m("lora", r=8),
                 _m("fourierft", n=64), _m("fourierft", n=128)):
        A(ArtifactSpec(DEC_MED, meth, "lm"))
    for meth in (_m("ff"), _m("adapter", m=8), _m("lora", r=4), _m("lora", r=8),
                 _m("fourierft", n=96), _m("fourierft", n=192)):
        A(ArtifactSpec(DEC_LARGE, meth, "lm"))

    # --- Table 13: DreamBooth-sim (denoiser fine-tuning, FID) --------------
    for meth in (_m("ff"), _m("lora", r=8), _m("fourierft", n=64)):
        A(ArtifactSpec(DENOISER, meth, "mseimg"))

    # --- Table 5: image classification (vit) -------------------------------
    for meth in (_m("lp"), _m("ff"), _m("lora", r=8),
                 _m("fourierft", n=96), _m("fourierft", n=384)):
        A(ArtifactSpec(VIT_BASE, meth, "ce"))
    for meth in (_m("lp"), _m("ff"), _m("lora", r=8),
                 _m("fourierft", n=144), _m("fourierft", n=576)):
        A(ArtifactSpec(VIT_LARGE, meth, "ce"))

    return specs


def manifest_dict() -> list[dict]:
    return [
        {"model": asdict(s.model), "method": asdict(s.method), "loss": s.loss,
         "name": s.name}
        for s in build_manifest()
    ]
