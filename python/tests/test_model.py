"""L2 correctness: model shapes, PEFT delta semantics, and train-step
behaviour (loss decreases; lr=0 is a pure eval; zero-init deltas preserve
the base function)."""

from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, train
from compile.configs import (ArtifactSpec, MethodCfg, ModelCfg, MLP, ENC_BASE,
                             DEC_MED, VIT_BASE, build_manifest)


def make_all(spec: ArtifactSpec, seed=0):
    base = layers.init_base(spec.model, jax.random.PRNGKey(seed))
    adapt = layers.init_adapt(spec.model, spec.method, spec.loss,
                              jax.random.PRNGKey(seed + 1))
    statics = OrderedDict()
    rng = np.random.default_rng(seed)
    for k, (dt, shape) in layers.static_shapes(spec.model, spec.method).items():
        if k == "entries":
            d = spec.model.d if spec.model.kind != "mlp" else spec.model.hidden
            flat = rng.choice(d * d, size=spec.method.n, replace=False)
            statics[k] = jnp.asarray(np.stack([flat // d, flat % d]), jnp.int32)
        else:
            statics[k] = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    scalars = OrderedDict(step=jnp.asarray(1.0), lr=jnp.asarray(1e-3),
                          lr_head=jnp.asarray(1e-3), wd=jnp.asarray(0.0),
                          scaling=jnp.asarray(1.0))
    batch = OrderedDict()
    for k, (dt, shape) in train.batch_shapes(spec).items():
        if dt == "i32":
            hi = spec.model.vocab if len(shape) > 1 or spec.model.kind == "decoder" else max(spec.model.classes, 2)
            if spec.model.kind in ("mlp", "vit") and k == "y":
                hi = spec.model.classes
            batch[k] = jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    if "mask" in batch:
        batch["mask"] = jnp.ones_like(batch["mask"])
    return base, adapt, statics, scalars, batch


METHODS = [MethodCfg("ff"), MethodCfg("bitfit"), MethodCfg("lp"),
           MethodCfg("adapter", m=4), MethodCfg("lora", r=2),
           MethodCfg("fourierft", n=24), MethodCfg("randbasis", n=24),
           MethodCfg("orthobasis", n=24)]

SMALL_ENC = ModelCfg(name="enc_t", kind="encoder", d=32, layers=2, heads=2,
                     dff=64, vocab=50, seqlen=8, classes=3, batch=4)
SMALL_DEC = ModelCfg(name="dec_t", kind="decoder", d=32, layers=2, heads=2,
                     dff=64, vocab=50, seqlen=8, batch=4)
SMALL_VIT = ModelCfg(name="vit_t", kind="vit", d=32, layers=2, heads=2,
                     dff=64, img=16, patch=4, classes=5, batch=4)
SMALL_MLP = ModelCfg(name="mlp_t", kind="mlp", hidden=16, classes=8, batch=4)


@pytest.mark.parametrize("method", METHODS, ids=lambda m: m.tag)
@pytest.mark.parametrize("cfg,loss", [(SMALL_ENC, "ce"), (SMALL_DEC, "lm"),
                                      (SMALL_VIT, "ce"), (SMALL_MLP, "ce")],
                         ids=["enc", "dec", "vit", "mlp"])
def test_forward_shapes(cfg, loss, method):
    spec = ArtifactSpec(cfg, method, loss)
    base, adapt, statics, scalars, batch = make_all(spec)
    logits = train.model_logits(spec, base, adapt, statics, scalars, batch)
    if loss == "lm":
        assert logits.shape == (cfg.batch, cfg.seqlen, cfg.vocab)
    else:
        assert logits.shape == (cfg.batch, cfg.classes)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("method", METHODS, ids=lambda m: m.tag)
def test_zero_init_preserves_base_function(method):
    """At step 0 every method (with zero-init deltas / B=0 / c=0 / zero-up
    adapters) must compute exactly the frozen-base forward."""
    spec = ArtifactSpec(SMALL_ENC, method, "ce")
    base, adapt, statics, scalars, batch = make_all(spec)
    lp_spec = ArtifactSpec(SMALL_ENC, MethodCfg("lp"), "ce")
    lp_adapt = OrderedDict((k, v) for k, v in adapt.items() if k.startswith("head."))
    got = train.model_logits(spec, base, adapt, statics, scalars, batch)
    want = train.model_logits(lp_spec, base, lp_adapt, OrderedDict(), scalars, batch)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("method", [MethodCfg("ff"), MethodCfg("lora", r=2),
                                    MethodCfg("fourierft", n=24)],
                         ids=lambda m: m.tag)
@pytest.mark.parametrize("cfg,loss", [(SMALL_ENC, "ce"), (SMALL_DEC, "lm"),
                                      (SMALL_MLP, "ce")], ids=["enc", "dec", "mlp"])
def test_loss_decreases(cfg, loss, method):
    spec = ArtifactSpec(cfg, method, loss)
    base, adapt, statics, scalars, batch = make_all(spec)
    scalars["lr"] = jnp.asarray(3e-3)
    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
    v = OrderedDict((k, jnp.zeros_like(v2)) for k, v2 in adapt.items())
    step = jax.jit(lambda a, m, v, s: train.train_step(spec, base, a, m, v,
                                                       statics, s, batch))
    losses = []
    for t in range(1, 31):
        scalars["step"] = jnp.asarray(float(t))
        adapt, m, v, loss_val, _ = step(adapt, m, v, scalars)
        losses.append(float(loss_val))
    assert losses[-1] < losses[0] * 0.9, losses[::10]


def test_lr_zero_is_pure_eval():
    spec = ArtifactSpec(SMALL_ENC, MethodCfg("fourierft", n=16), "ce")
    base, adapt, statics, scalars, batch = make_all(spec)
    scalars["lr"] = jnp.asarray(0.0)
    scalars["lr_head"] = jnp.asarray(0.0)
    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
    v = OrderedDict((k, jnp.zeros_like(v2)) for k, v2 in adapt.items())
    a2, _, _, loss, logits = train.train_step(spec, base, adapt, m, v, statics,
                                              scalars, batch)
    for k in adapt:
        np.testing.assert_array_equal(adapt[k], a2[k])
    want = train.model_logits(spec, base, adapt, statics, scalars, batch)
    np.testing.assert_allclose(logits, want, rtol=1e-6)


def test_ff_on_delta_equals_training_weights():
    """Adam on a zero-init delta == Adam on the weight itself (translation
    invariance) — validates the uniform 'everything is a delta' design."""
    spec = ArtifactSpec(SMALL_MLP, MethodCfg("ff"), "ce")
    base, adapt, statics, scalars, batch = make_all(spec)
    scalars["lr"] = jnp.asarray(1e-2)
    scalars["lr_head"] = jnp.asarray(1e-2)  # uniform rate for exact equivalence

    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
    v = OrderedDict((k, jnp.zeros_like(x)) for k, x in adapt.items())
    a = adapt
    for t in range(1, 6):
        scalars["step"] = jnp.asarray(float(t))
        a, m, v, _, _ = train.train_step(spec, base, a, m, v, statics, scalars, batch)

    # Direct formulation: train the weights themselves.
    def direct_loss(params):
        h = jnp.tanh(batch["x"] @ params["w1.w"] + params["w1.b"])
        h = jnp.tanh(h @ params["w2.w"] + params["w2.b"])
        logits = h @ params["head.w"] + params["head.b"]
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.take_along_axis(lp, batch["y"][:, None], -1).mean()

    params = {k: base[k] for k in base}
    m2 = {k: jnp.zeros_like(x) for k, x in params.items()}
    v2 = {k: jnp.zeros_like(x) for k, x in params.items()}
    for t in range(1, 6):
        g = jax.grad(direct_loss)(params)
        for k in params:
            m2[k] = 0.9 * m2[k] + 0.1 * g[k]
            v2[k] = 0.999 * v2[k] + 0.001 * g[k] ** 2
            mh = m2[k] / (1 - 0.9 ** t)
            vh = v2[k] / (1 - 0.999 ** t)
            params[k] = params[k] - 1e-2 * mh / (jnp.sqrt(vh) + 1e-8)

    np.testing.assert_allclose(base["w2.w"] + a["delta.w2.w"], params["w2.w"],
                               rtol=1e-4, atol=1e-5)


def test_trainable_param_counts_match_theory():
    """Paper §3.2: |Θ|_FourierFT = n * L_t, |Θ|_LoRA = 2 d r L_t (ex head)."""
    lt = 2 * ENC_BASE.layers  # W_q and W_v per block
    from compile.aot import trainable_counts

    c_fft = trainable_counts(ArtifactSpec(ENC_BASE, MethodCfg("fourierft", n=64), "ce"))
    assert c_fft["trainable_ex_head"] == 64 * lt

    c_lora = trainable_counts(ArtifactSpec(ENC_BASE, MethodCfg("lora", r=4), "ce"))
    assert c_lora["trainable_ex_head"] == 2 * ENC_BASE.d * 4 * lt


def test_manifest_names_unique():
    names = [s.name for s in build_manifest()]
    assert len(names) == len(set(names))


def test_adapted_sites_query_value_only():
    keys = layers.adapted_weight_keys(ENC_BASE)
    assert all(("attn.wq" in k) or ("attn.wv" in k) for k in keys)
    assert len(keys) == 2 * ENC_BASE.layers
