"""L1 correctness: the Pallas spectral-reconstruction kernel vs the pure-jnp
oracle (``ref.py``, which mirrors the paper's ``torch.fft.ifft2`` semantics).

Hypothesis sweeps shapes / n / alpha / block sizes; the oracle itself is
cross-checked (ifft2 form vs trig-matmul form) so a shared bug in both
derivations would have to fool two independent formulations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fourier, ref
from compile import layers

jax.config.update("jax_enable_x64", False)


def random_spectrum(seed: int, d1: int, d2: int, n: int):
    rng = np.random.default_rng(seed)
    flat = rng.choice(d1 * d2, size=n, replace=False)
    entries = jnp.asarray(np.stack([flat // d2, flat % d2]), jnp.int32)
    coeffs = jnp.asarray(rng.standard_normal(n), jnp.float32)
    return entries, coeffs


@settings(max_examples=25, deadline=None)
@given(
    d1=st.sampled_from([8, 17, 64, 96, 128]),
    d2=st.sampled_from([8, 24, 64, 100, 128]),
    n_frac=st.floats(0.01, 0.5),
    alpha=st.floats(0.1, 300.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_matches_ifft_oracle(d1, d2, n_frac, alpha, seed):
    n = max(1, int(d1 * d2 * n_frac))
    entries, coeffs = random_spectrum(seed, d1, d2, n)
    got = fourier.spectral_to_delta(entries, coeffs, alpha, d1=d1, d2=d2)
    want = ref.spectral_to_delta_ifft(entries, coeffs, d1, d2, alpha)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * alpha)


@settings(max_examples=15, deadline=None)
@given(
    d=st.sampled_from([16, 32, 64]),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_oracles_agree(d, n, seed):
    """ifft2 formulation == trig-matmul formulation (independent derivations)."""
    entries, coeffs = random_spectrum(seed, d, d, n)
    a = ref.spectral_to_delta_ifft(entries, coeffs, d, d, 5.0)
    b = ref.spectral_to_delta_matmul(entries, coeffs, d, d, 5.0)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block", [(8, 8, 16), (16, 32, 64), (64, 64, 128), (128, 128, 256)])
def test_block_shapes_equivalent(block):
    """Tiling must not change numerics (reduction reassociation only)."""
    entries, coeffs = random_spectrum(7, 96, 80, 200)
    want = ref.spectral_to_delta_ifft(entries, coeffs, 96, 80, 2.0)
    got = fourier.spectral_to_delta(entries, coeffs, 2.0, d1=96, d2=80, block=block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_n_larger_than_block_padding():
    entries, coeffs = random_spectrum(3, 32, 32, 5)  # n=5 << bk
    want = ref.spectral_to_delta_ifft(entries, coeffs, 32, 32, 1.0)
    got = fourier.spectral_to_delta(entries, coeffs, 1.0, d1=32, d2=32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_zero_coeffs_give_zero_delta():
    entries, _ = random_spectrum(0, 64, 64, 32)
    got = fourier.spectral_to_delta(entries, jnp.zeros(32), 300.0, d1=64, d2=64)
    assert float(jnp.abs(got).max()) == 0.0


def test_alpha_scales_linearly():
    entries, coeffs = random_spectrum(1, 48, 48, 64)
    g1 = fourier.spectral_to_delta(entries, coeffs, 1.0, d1=48, d2=48)
    g7 = fourier.spectral_to_delta(entries, coeffs, 7.0, d1=48, d2=48)
    np.testing.assert_allclose(7.0 * g1, g7, rtol=1e-5, atol=1e-6)


def test_delta_is_real_even_for_asymmetric_spectrum():
    """Re() of an IDFT of a real (non-hermitian) sparse spectrum: kernel must
    equal the real part exactly, not assume conjugate symmetry."""
    entries = jnp.asarray([[1], [3]], jnp.int32)  # single off-axis entry
    coeffs = jnp.asarray([1.0], jnp.float32)
    got = fourier.spectral_to_delta(entries, coeffs, 1.0, d1=8, d2=8)
    want = ref.spectral_to_delta_ifft(entries, coeffs, 8, 8, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Gradient path (custom VJP): analytic adjoint vs finite differences and vs
# autodiff through the dense oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d1,d2,n", [(16, 16, 8), (32, 24, 40), (64, 64, 64)])
def test_custom_vjp_matches_oracle_grad(d1, d2, n):
    entries, coeffs = random_spectrum(11, d1, d2, n)
    g = jnp.asarray(np.random.default_rng(0).standard_normal((d1, d2)), jnp.float32)

    def via_kernel(c):
        return (layers.fourier_delta(entries, c, 3.0, d1, d2) * g).sum()

    def via_oracle(c):
        return (ref.spectral_to_delta_ifft(entries, c, d1, d2, 3.0) * g).sum()

    gk = jax.grad(via_kernel)(coeffs)
    go = jax.grad(via_oracle)(coeffs)
    np.testing.assert_allclose(gk, go, rtol=1e-3, atol=1e-4)


def test_custom_vjp_finite_difference():
    d, n = 24, 12
    entries, coeffs = random_spectrum(5, d, d, n)
    g = jnp.asarray(np.random.default_rng(2).standard_normal((d, d)), jnp.float32)

    def f(c):
        return float((layers.fourier_delta(entries, c, 2.0, d, d) * g).sum())

    grad = jax.grad(lambda c: (layers.fourier_delta(entries, c, 2.0, d, d) * g).sum())(coeffs)
    eps = 1e-2
    for i in range(0, n, 3):
        e = np.zeros(n, np.float32)
        e[i] = eps
        fd = (f(coeffs + e) - f(coeffs - e)) / (2 * eps)
        assert abs(fd - float(grad[i])) < 5e-3, (i, fd, float(grad[i]))


# ---------------------------------------------------------------------------
# Structural / roofline invariants
# ---------------------------------------------------------------------------


def test_vmem_budget_default_block():
    assert fourier.vmem_bytes((64, 64, 128)) < 1 << 20  # << 16 MiB VMEM


def test_mxu_flops_formula():
    assert fourier.mxu_flops(768, 768, 1000) == 4 * 768 * 768 * 1000


def test_basis_delta_oracle_orthogonal_roundtrip():
    """With the (unitary-scaled) DFT cos basis replaced by identity, the basis
    form reduces to the dense spectral matrix itself."""
    d, n = 16, 10
    entries, coeffs = random_spectrum(9, d, d, n)
    eye = jnp.eye(d, dtype=jnp.float32)
    got = ref.basis_delta(entries, coeffs, eye, eye, 1.0)
    want = ref.to_dense(entries, coeffs, d, d)
    np.testing.assert_allclose(got, want, atol=1e-6)
