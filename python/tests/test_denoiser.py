"""Denoiser (Table 13 / DreamBooth-sim) model semantics."""

from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers, train
from compile.configs import ArtifactSpec, MethodCfg, ModelCfg

DN = ModelCfg(name="dn_t", kind="denoiser", hidden=32, img=8, channels=3, batch=4)
PIX = 8 * 8 * 3


def setup(method):
    spec = ArtifactSpec(DN, method, "mseimg")
    base = layers.init_base(DN, jax.random.PRNGKey(0))
    adapt = layers.init_adapt(DN, method, "mseimg", jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    statics = OrderedDict()
    for k, (dt, shape) in layers.static_shapes(DN, method).items():
        if k == "entries":
            flat = rng.choice(32 * 32, size=method.n, replace=False)
            statics[k] = jnp.asarray(np.stack([flat // 32, flat % 32]), jnp.int32)
        else:
            statics[k] = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    scalars = OrderedDict(step=jnp.asarray(1.0), lr=jnp.asarray(1e-2),
                          lr_head=jnp.asarray(1e-2), wd=jnp.asarray(0.0),
                          scaling=jnp.asarray(4.0))
    clean = jnp.asarray(rng.random((4, PIX)), jnp.float32)
    noisy = jnp.clip(clean + 0.3 * jnp.asarray(rng.standard_normal((4, PIX)), jnp.float32), 0, 1)
    batch = OrderedDict(x=noisy, y=clean)
    return spec, base, adapt, statics, scalars, batch


@pytest.mark.parametrize("method", [MethodCfg("ff"), MethodCfg("lora", r=2),
                                    MethodCfg("fourierft", n=16)],
                         ids=lambda m: m.tag)
def test_output_shape_and_range(method):
    spec, base, adapt, statics, scalars, batch = setup(method)
    out = train.model_logits(spec, base, adapt, statics, scalars, batch)
    assert out.shape == (4, PIX)
    assert bool((out >= 0).all() and (out <= 1).all()), "sigmoid output range"


def test_denoiser_has_no_trainable_head():
    adapt = layers.init_adapt(DN, MethodCfg("fourierft", n=16), "mseimg",
                              jax.random.PRNGKey(0))
    assert all(not k.startswith("head.") for k in adapt)
    assert list(adapt) == ["spec.w2.w.c"]


@pytest.mark.parametrize("method,factor", [(MethodCfg("ff"), 0.9),
                                           (MethodCfg("fourierft", n=32), 0.999)],
                         ids=["ff", "fourierft_n32"])
def test_denoising_loss_decreases(method, factor):
    # ff has full capacity (0.9x in 40 steps); 32 spectral coefficients on a
    # RANDOM (unpretrained) base can only nudge the loss — assert direction.
    spec, base, adapt, statics, scalars, batch = setup(method)
    if method.name == "fourierft":
        scalars["scaling"] = jnp.asarray(64.0)
        scalars["lr"] = jnp.asarray(5e-2)
    m = OrderedDict((k, jnp.zeros_like(v)) for k, v in adapt.items())
    v = OrderedDict((k, jnp.zeros_like(v2)) for k, v2 in adapt.items())
    step = jax.jit(lambda a, m, v, s: train.train_step(spec, base, a, m, v,
                                                       statics, s, batch))
    losses = []
    for t in range(1, 41):
        scalars["step"] = jnp.asarray(float(t))
        adapt, m, v, loss, _ = step(adapt, m, v, scalars)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * factor, losses[::10]


def test_mseimg_loss_is_pixel_mse():
    spec, base, adapt, statics, scalars, batch = setup(MethodCfg("ff"))
    logits = train.model_logits(spec, base, adapt, statics, scalars, batch)
    want = float(((logits - batch["y"]) ** 2).mean())
    got = float(train.compute_loss(spec, logits, batch))
    assert abs(want - got) < 1e-7
