//! Adapter zoo: the storage story from the paper's introduction, measured.
//!
//! Fine-tunes one adapter per GLUE-sim task with three methods (FourierFT,
//! LoRA, full dense delta), publishes all of them to a
//! [`SharedAdapterStore`], and prints the bytes a "Civitai for adapters"
//! would have to store and ship per fine-tune — then serves a mixed
//! request queue across all FourierFT adapters through the micro-batching
//! scheduler, reporting router statistics.
//!
//! Run: `cargo run --example adapter_zoo -- [--steps 60]`

use fourier_peft::adapter::{AdapterFile, SharedAdapterStore};
use fourier_peft::coordinator::experiments::{glue_run, Opts};
use fourier_peft::coordinator::serving::{Request, Server};
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::data::collate_text;
use fourier_peft::data::glue::GlueTask;
use fourier_peft::util::{cli::Args, fmt_bytes};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 60);
    let trainer = Trainer::open_default()?;
    let opts = Opts { steps, seeds: 1, eval_count: 128, quick: true, scaling_scale: 1.0 };
    let store_dir = fourier_peft::runs_dir().join("zoo");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = SharedAdapterStore::open(&store_dir)?;

    let tasks = [GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Rte, GlueTask::Qnli];
    // (registered method id, training artifact) — the method id is all the
    // save path needs; the registry owns the per-method tensor grammar.
    let methods: [(&str, &str); 3] = [
        ("fourierft", "enc_base__fourierft_n64__ce"),
        ("lora", "enc_base__lora_r8__ce"),
        ("dense", "enc_base__ff__ce"),
    ];

    println!("{:<10} {:<8} {:>10} {:>12} {:>8}", "method", "task", "metric", "bytes", "vs fft");
    let mut fft_bytes = 0usize;
    for (mname, artifact) in methods {
        let site_dims = trainer.registry.meta(artifact)?.site_dims();
        for task in tasks {
            let res = glue_run(&trainer, task, artifact, &opts, 0, 1.0)?;
            let file = AdapterFile::from_named(
                mname,
                2024,
                8.0,
                vec![("task".into(), task.name().into())],
                // paper convention: adapters exclude the task head for byte
                // accounting (heads are tiny and method-independent)
                res.adapt.into_iter().filter(|(k, _)| !k.starts_with("head.")).collect(),
                |site| site_dims.get(site).copied(),
            )?;
            let bytes = store.save(&format!("{mname}_{}", task.name()), &file)?;
            if mname == "fourierft" {
                fft_bytes = bytes;
            }
            println!(
                "{:<10} {:<8} {:>9.1}% {:>12} {:>7.1}x",
                mname,
                task.name(),
                100.0 * res.best_eval,
                fmt_bytes(bytes),
                bytes as f64 / fft_bytes.max(1) as f64
            );
        }
    }
    println!("\nstore total: {}", fmt_bytes(store.total_bytes()? as usize));

    // --- serve a mixed queue over the FourierFT adapters ------------------
    let mut server = Server::new(&trainer, "enc_base__fourierft_n64__ce", store, 2024, 8.0)?;
    let meta = trainer.registry.meta("enc_base__fourierft_n64__ce")?.clone();
    let queue: Vec<Request> = (0..16)
        .map(|i| {
            let task = tasks[i % tasks.len()];
            Request {
                id: i as u64,
                adapter: format!("fourierft_{}", task.name()),
                batch: collate_text(&task.split("val", meta.model.batch, i as u64), meta.model.seqlen),
            }
        })
        .collect();
    let (results, stats) = server.serve(queue)?;
    println!(
        "served {} requests  swaps {} ({:.1} ms total)  exec {:.1} ms  throughput {:.1} req/s",
        results.len(),
        stats.swaps,
        1e3 * stats.swap_seconds,
        1e3 * stats.exec_seconds,
        stats.throughput_rps()
    );
    Ok(())
}
