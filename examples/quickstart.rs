//! Quickstart: the 60-second tour of fourier-peft.
//!
//! 1. open the artifact registry (built once by `make artifacts`),
//! 2. fine-tune a FourierFT adapter (n=128 spectral coefficients) on the
//!    Figure-7 synthetic task,
//! 3. save the adapter (~a few hundred bytes of coefficients!),
//! 4. reload it and serve a prediction.
//!
//! Run: `cargo run --example quickstart`

use fourier_peft::adapter::{AdapterFile, AdapterStore};
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::data::blobs;
use fourier_peft::metrics::classify;
use fourier_peft::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // --- 1. runtime + registry ------------------------------------------
    let trainer = Trainer::open_default()?;
    println!("PJRT platform: {}", trainer.client.platform());

    // --- 2. fine-tune: FourierFT with n=128 spectral coefficients --------
    let artifact = "mlp__fourierft_n128__ce";
    let mut cfg = FinetuneCfg::new(artifact);
    cfg.lr = 0.05;
    cfg.scaling = 64.0; // the paper's alpha
    cfg.steps = 200;
    cfg.entry_seed = 2024; // the paper's shared entry seed
    println!("fine-tuning {artifact} for {} steps ...", cfg.steps);
    let result = trainer.finetune(
        &cfg,
        |step, _rng| blobs::collate(&blobs::dataset(64, 0.35, step as u64)),
        None,
    )?;
    println!(
        "loss {:.3} -> {:.3} in {:.1}s",
        result.losses.first().unwrap(),
        result.losses.last().unwrap(),
        result.train_seconds
    );

    // --- 3. save the adapter --------------------------------------------
    // format v2 is self-describing: the method id, each tensor's
    // (site, role), and each site's weight dims go into the file.
    let mut store = AdapterStore::open(&fourier_peft::runs_dir().join("quickstart"))?;
    let site_dims = trainer.executable(artifact)?.meta.site_dims();
    let file = AdapterFile::from_named(
        "fourierft",
        cfg.entry_seed,
        cfg.scaling,
        vec![("task".into(), "blobs8".into()), ("n".into(), "128".into())],
        result.adapt,
        |site| site_dims.get(site).copied(),
    )?;
    let bytes = store.save("blobs8", &file)?;
    println!("adapter saved: {} ({} trainable coefficients/site)", fmt_bytes(bytes), 128);

    // --- 4. reload + serve ----------------------------------------------
    let exe = trainer.executable(artifact)?;
    let (statics, _) = trainer.make_statics(&exe.meta, cfg.entry_seed, cfg.bias)?;
    let base = trainer.base_for(&exe.meta)?;
    let mut state = exe.init_state(0, base, statics)?;
    let reloaded = store.load("blobs8")?;
    exe.set_adapt(
        &mut state,
        &reloaded.tensors.into_iter().map(|e| (e.name, e.tensor)).collect(),
    )?;

    let pts = blobs::dataset(64, 0.35, 0xDEED);
    let out = exe.eval(&mut state, cfg.scaling, &blobs::collate(&pts))?;
    let preds = classify::argmax_rows(out.logits.as_f32()?, 8);
    let labels: Vec<i32> = pts.iter().map(|p| p.class as i32).collect();
    println!("served accuracy: {:.1}%", 100.0 * classify::accuracy(&preds, &labels));
    Ok(())
}
