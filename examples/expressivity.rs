//! Expressivity head-to-head (paper Figure 7 + §4.5): at an *equal*
//! trainable-parameter budget, FourierFT's spectral parameterization covers
//! weight-change directions a rank-1 LoRA cannot.
//!
//! Trains LoRA r=1 (128 params/site) vs FourierFT n=128 (128 params/site)
//! vs FF on the 8-class blobs task and prints accuracy trajectories side
//! by side, plus the reconstruction-rank analysis: the effective rank of
//! the FourierFT ΔW vs LoRA's rank-1 ΔW.
//!
//! Run: `cargo run --example expressivity -- [--steps 400]`

use fourier_peft::adapter::merge::delta_host;
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::data::blobs;
use fourier_peft::metrics::classify;
use fourier_peft::tensor::Tensor;
use fourier_peft::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 400);
    let trainer = Trainer::open_default()?;
    let eval_pts = blobs::dataset(512, 0.35, 0xE);
    let eval_batches: Vec<_> = eval_pts.chunks(64).map(blobs::collate).collect();

    let mut trajectories: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    let mut fft_adapt: Option<Vec<(String, Tensor)>> = None;
    for (label, artifact, lr, scaling) in [
        ("LoRA r=1", "mlp__lora_r1__ce", 1e-2f32, 2.0f32),
        ("FourierFT n=128", "mlp__fourierft_n128__ce", 5e-2, 64.0),
        ("FF", "mlp__ff__ce", 1e-2, 1.0),
    ] {
        let mut cfg = FinetuneCfg::new(artifact);
        cfg.lr = lr;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.eval_every = (steps / 20).max(1);
        cfg.seed = 7;
        let tr = &trainer;
        let eval_ref = &eval_batches;
        let mut eval_fn = move |exe: &fourier_peft::runtime::Executable,
                                state: &mut fourier_peft::runtime::exec::ParamSet,
                                scaling: f32|
              -> anyhow::Result<f64> {
            let (preds, labels, _, _) = tr.eval_classify(exe, state, scaling, eval_ref)?;
            Ok(classify::accuracy(&preds, &labels))
        };
        let res = trainer.finetune(
            &cfg,
            |step, _| blobs::collate(&blobs::dataset(64, 0.35, 0xF00 ^ (step as u64) << 13)),
            Some(&mut eval_fn),
        )?;
        println!("{label:<18} final {:.1}%  best {:.1}%",
                 100.0 * res.final_eval, 100.0 * res.best_eval);
        if label.starts_with("FourierFT") {
            fft_adapt = Some(res.adapt.clone());
        }
        trajectories.push((label.to_string(), res.evals));
    }

    // side-by-side trajectory table
    println!("\nstep      {}", trajectories.iter().map(|(l, _)| format!("{l:<18}")).collect::<String>());
    let max_len = trajectories.iter().map(|(_, e)| e.len()).max().unwrap_or(0);
    for i in 0..max_len {
        let step = trajectories[0].1.get(i).map(|(s, _)| *s).unwrap_or(0);
        let mut line = format!("{step:<9} ");
        for (_, evals) in &trajectories {
            if let Some((_, acc)) = evals.get(i) {
                line.push_str(&format!("{:<18.3}", acc));
            }
        }
        println!("{line}");
    }

    // Effective rank of the learned FourierFT ΔW vs LoRA's structural rank 1.
    if let Some(adapt) = fft_adapt {
        if let Some((_, coeffs)) = adapt.iter().find(|(k, _)| k == "spec.w2.w.c") {
            let delta = delta_host(coeffs, 2024, 128, 64, 64, 64.0)?;
            let erank = effective_rank(&delta)?;
            println!(
                "\nΔW analysis: FourierFT n=128 produces effective rank ≈ {erank:.1} \
                 (LoRA r=1 is rank 1 by construction) — the expressivity gap of Fig. 7"
            );
        }
    }
    Ok(())
}

/// Effective rank via the entropy of the singular-value spectrum,
/// exp(H(sigma^2 / sum sigma^2)), estimated with power iteration deflation.
fn effective_rank(m: &Tensor) -> anyhow::Result<f64> {
    // cheap estimate: Frobenius vs spectral norms over a few power iters
    let d = m.shape[0];
    let data = m.as_f32()?;
    // Gram matrix eigenvalues via Jacobi-ish power deflation (top 16)
    let mut gram = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..d {
            let mut acc = 0.0f64;
            for k in 0..d {
                acc += data[i * d + k] as f64 * data[j * d + k] as f64;
            }
            gram[i * d + j] = acc;
        }
    }
    let mut eigs = Vec::new();
    let mut g = gram;
    for t in 0..16 {
        let mut v = vec![1.0f64 / (d as f64).sqrt(); d];
        let mut lambda = 0.0;
        for _ in 0..50 {
            let mut nv = vec![0.0f64; d];
            for i in 0..d {
                for j in 0..d {
                    nv[i] += g[i * d + j] * v[j];
                }
            }
            lambda = nv.iter().map(|x| x * x).sum::<f64>().sqrt();
            if lambda < 1e-12 {
                break;
            }
            for (vi, nvi) in v.iter_mut().zip(&nv) {
                *vi = nvi / lambda;
            }
        }
        if lambda < 1e-12 {
            break;
        }
        eigs.push(lambda);
        // deflate
        for i in 0..d {
            for j in 0..d {
                g[i * d + j] -= lambda * v[i] * v[j];
            }
        }
        let _ = t;
    }
    let total: f64 = eigs.iter().sum();
    let h: f64 = eigs
        .iter()
        .filter(|&&e| e > 1e-12)
        .map(|e| {
            let p = e / total;
            -p * p.ln()
        })
        .sum();
    Ok(h.exp())
}
