//! End-to-end driver (the repo's flagship validation): pretrain → fine-tune
//! → generate → score, across the full three-layer stack.
//!
//! * pretrains (or loads the cached) decoder backbone on the broad
//!   synthetic corpus — next-token LM, loss curve logged,
//! * fine-tunes it on the E2E-sim data-to-text task with FourierFT (n=64)
//!   and with LoRA (r=4) for comparison,
//! * greedy-generates utterances for held-out slot tables,
//! * reports BLEU / NIST / METEOR / ROUGE-L / CIDEr for both methods plus
//!   the trainable-parameter ratio — Table 3 in miniature.
//!
//! Run: `cargo run --example e2e_finetune -- [--steps 300]`

use fourier_peft::coordinator::generate;
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::data::{collate_lm, e2e};
use fourier_peft::metrics::nlg;
use fourier_peft::util::{cli::Args, fmt_params};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300);
    let trainer = Trainer::open_default()?;

    println!("=== E2E-sim fine-tuning (decoder dec_med, T=48, vocab=1000) ===");
    for (label, artifact, lr, scaling) in [
        ("FourierFT n=64", "dec_med__fourierft_n64__lm", 5e-2f32, 8.0f32),
        ("LoRA r=4", "dec_med__lora_r4__lm", 5e-3, 2.0),
    ] {
        let meta = trainer.registry.meta(artifact)?.clone();
        let seqlen = meta.model.seqlen;
        let b = meta.model.batch;
        let mut cfg = FinetuneCfg::new(artifact);
        cfg.lr = lr;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.seed = 1;

        println!("\n--- {label}: {} trainable params (ex head) ---",
                 fmt_params(meta.trainable_ex_head));
        let result = trainer.finetune(
            &cfg,
            move |step, _rng| {
                let mrs = e2e::split("train", b, (step as u64) << 9 ^ 0xE2);
                collate_lm(&e2e::examples(&mrs, seqlen, step as u64), seqlen)
            },
            None,
        )?;
        // log a loss curve sample (the "end-to-end validation" record)
        let every = (steps / 10).max(1);
        for (i, l) in result.losses.iter().enumerate() {
            if i % every == 0 || i + 1 == result.losses.len() {
                println!("  step {:>4}  lm-loss {l:.4}", i + 1);
            }
        }

        // generation on held-out MRs
        let exe = trainer.executable(artifact)?;
        let (statics, _) = trainer.make_statics(&exe.meta, cfg.entry_seed, cfg.bias)?;
        let base = trainer.base_for(&exe.meta)?;
        let mut state = exe.init_state(cfg.seed as i32, base, statics)?;
        exe.set_adapt(&mut state, &result.adapt.into_iter().collect())?;

        let test_mrs = e2e::split("test", 64, 0xE2);
        let mut hyps = Vec::new();
        let mut refs = Vec::new();
        for chunk in test_mrs.chunks(b) {
            let prompts: Vec<Vec<i32>> = chunk.iter().map(|m| m.prompt()).collect();
            let outs = generate::greedy(&exe, &mut state, cfg.scaling, &prompts, 12)?;
            for (mr, mut g) in chunk.iter().zip(outs) {
                if g.last() == Some(&fourier_peft::data::vocab::EOS) {
                    g.pop();
                }
                hyps.push(g);
                refs.push(mr.references().into_iter().map(|mut r| { r.pop(); r }).collect());
            }
        }
        let s = nlg::score_all(&hyps, &refs);
        println!(
            "  BLEU {:.1}  NIST {:.2}  METEOR {:.1}  ROUGE-L {:.1}  CIDEr {:.2}",
            s.bleu, s.nist, s.meteor, s.rouge_l, s.cider
        );
        // show one sample generation, detokenized
        let v = fourier_peft::data::vocab::vocab();
        println!("  sample MR    : {}", v.detok(&test_mrs[0].prompt()));
        println!("  generated    : {}", v.detok(&hyps[0]));
        println!("  reference    : {}", v.detok(&refs[0][0]));
    }
    Ok(())
}
